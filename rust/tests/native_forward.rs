//! Differential + property suite for the native tiny-MoE forward pass
//! (`runtime::forward`), the computation behind `dsq eval --native`.
//!
//! Four locks, mirroring the codec golden suite one level up:
//!
//! 1. **Golden logits** — the shared script (prefill [`PROMPT`] on the
//!    seed-`0x601D` tiny-moe container, then greedy decode) must hash
//!    to the committed `tests/golden/forward.*.fnv64` checksums for the
//!    DQ3_K_M and Q4_K_M schemes. The committed fixtures were produced
//!    by the bit-exact Python mirror in `python/tools/bless_goldens.py`,
//!    so this test is also the Rust↔Python cross-language gate.
//! 2. **Differential vs an in-test f64 reference** — an independent
//!    plain-loop float64 forward (libm transcendentals, natural-order
//!    sums, no shared code with the engine) must agree to ~1e-4 on the
//!    *same* decoded weights, and within the per-scheme quantization
//!    tolerance on the f32 *source* weights (measured rel-L2 ≈ 0.11 for
//!    DQ3_K_M / 0.12 for Q4_K_M on this fixture).
//! 3. **Bit identity** — logits are identical across matvec thread
//!    counts {1, 2, 8} and across both pinned vec_dot dispatch arms;
//!    CI reruns this whole suite under `DSQ_SCALAR_DECODE=1` so the
//!    env-selected scalar arm is pinned to the same fixtures.
//! 4. **KV-cache coherence** — incremental decode (logits requested at
//!    every step) is bit-identical to a fresh full prefill of the same
//!    token prefix, and attention state actually matters (the same
//!    token at different positions produces different logits).

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::sampler::argmax;
use dsq::model::ModelConfig;
use dsq::runtime::forward::{ForwardPass, MatvecMode};
use dsq::runtime::native::NATIVE_MAX_CTX;
use dsq::util::fnv64;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::OnceLock;

/// The golden script, mirrored verbatim by `bless_goldens.py`.
const PROMPT: [i32; 8] = [1, 17, 300, 42, 511, 7, 5, 260];
const DECODE_STEPS: usize = 4;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn golden_src() -> Container {
    synthetic_f32_container(&ModelConfig::tiny_moe(), 0x601D).unwrap()
}

/// Quantized golden-container bytes, built once per scheme.
fn qbytes(scheme: &str) -> &'static [u8] {
    static DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static Q4: OnceLock<Vec<u8>> = OnceLock::new();
    let cell = match scheme {
        "dq3_k_m" => &DQ3,
        "q4_k_m" => &Q4,
        other => panic!("unexpected scheme {other}"),
    };
    cell.get_or_init(|| {
        let scheme = dsq::scheme::builtin::scheme(scheme).unwrap();
        quantize_container_with(&golden_src(), &scheme, None, 1).unwrap().to_bytes()
    })
}

fn forward(scheme: &str, threads: usize) -> ForwardPass {
    let ckpt = Container::from_bytes(qbytes(scheme).to_vec()).unwrap();
    ForwardPass::new(ckpt, threads, NATIVE_MAX_CTX).unwrap()
}

/// Run the golden script: prefill `PROMPT` (logits at the last prompt
/// token only), then `DECODE_STEPS` greedy steps. Returns the emitted
/// logits rows (1 + DECODE_STEPS of them).
fn run_script(fwd: &ForwardPass) -> Vec<Vec<f32>> {
    let mut cache = fwd.new_cache();
    let mut logits = vec![0f32; fwd.vocab()];
    for (j, &t) in PROMPT.iter().enumerate() {
        let want = if j + 1 == PROMPT.len() { Some(&mut logits[..]) } else { None };
        fwd.forward_token(t, &mut cache, want).unwrap();
    }
    let mut rows = vec![logits.clone()];
    for _ in 0..DECODE_STEPS {
        let tok = argmax(rows.last().unwrap());
        fwd.forward_token(tok, &mut cache, Some(&mut logits)).unwrap();
        rows.push(logits.clone());
    }
    rows
}

fn bits(rows: &[Vec<f32>]) -> Vec<u32> {
    rows.iter().flatten().map(|v| v.to_bits()).collect()
}

#[test]
fn golden_forward_logits_checksums() {
    for scheme in ["dq3_k_m", "q4_k_m"] {
        let rows = run_script(&forward(scheme, 1));
        let mut blob = Vec::with_capacity(rows.len() * rows[0].len() * 4);
        for r in &rows {
            for v in r {
                blob.extend_from_slice(&v.to_le_bytes());
            }
        }
        let line = format!("{:016x} {}\n", fnv64(&blob), blob.len());
        let path = golden_dir().join(format!("forward.{scheme}.fnv64"));
        if !path.exists() {
            std::fs::write(&path, &line).unwrap();
            eprintln!("[golden] blessed new fixture {} — commit it", path.display());
            continue;
        }
        let expect = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            expect.trim(),
            line.trim(),
            "forward logits for scheme {scheme} drifted from {}; if the change is \
             intentional, re-bless from python/tools/bless_goldens.py (or delete + rerun) \
             and call it out in the PR",
            path.display()
        );
    }
}

#[test]
fn logits_bit_identical_across_threads_and_dispatch_arms() {
    let base = bits(&run_script(&forward("dq3_k_m", 1)));
    for (label, mode) in [
        ("threads=2", MatvecMode::Threads(2)),
        ("threads=8", MatvecMode::Threads(8)),
        ("pinned scalar arm", MatvecMode::Pinned(false)),
        ("pinned lane arm", MatvecMode::Pinned(true)),
    ] {
        let mut fwd = forward("dq3_k_m", 1);
        fwd.set_mode(mode);
        assert_eq!(base, bits(&run_script(&fwd)), "{label}");
    }
}

#[test]
fn incremental_decode_equals_full_prefill() {
    let fwd = forward("q4_k_m", 2);
    let toks = [1i32, 9, 300, 42, 77, 5];
    // Incremental: one cache, logits requested at every step.
    let mut cache = fwd.new_cache();
    let mut logits = vec![0f32; fwd.vocab()];
    let mut per_step: Vec<Vec<u32>> = Vec::new();
    for &t in &toks {
        fwd.forward_token(t, &mut cache, Some(&mut logits)).unwrap();
        per_step.push(logits.iter().map(|v| v.to_bits()).collect());
    }
    // Fresh prefills of each prefix (logits only at the final token)
    // must land on the same bits: requesting logits mid-stream does not
    // perturb the cache, and the cache replays exactly.
    for k in [1usize, 3, 6] {
        let mut c2 = fwd.new_cache();
        for (j, &t) in toks[..k].iter().enumerate() {
            let want = if j + 1 == k { Some(&mut logits[..]) } else { None };
            fwd.forward_token(t, &mut c2, want).unwrap();
        }
        let got: Vec<u32> = logits.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, per_step[k - 1], "prefix length {k}");
        assert_eq!(c2.len(), k);
    }
}

#[test]
fn attention_state_makes_positions_distinct() {
    let fwd = forward("q4_k_m", 1);
    let mut cache = fwd.new_cache();
    let mut first = vec![0f32; fwd.vocab()];
    let mut second = vec![0f32; fwd.vocab()];
    fwd.forward_token(42, &mut cache, Some(&mut first)).unwrap();
    fwd.forward_token(42, &mut cache, Some(&mut second)).unwrap();
    assert_ne!(
        bits(&[first]),
        bits(&[second]),
        "same token at positions 0 and 1 must see different attention state"
    );
}

// --- the independent f64 reference forward -------------------------------

/// Every tensor of a container decoded to f64 (shape kept).
fn decode_all(c: &Container) -> HashMap<String, (Vec<usize>, Vec<f64>)> {
    c.tensors
        .iter()
        .map(|t| {
            let vals: Vec<f64> = c.dequantize(t).unwrap().iter().map(|&v| v as f64).collect();
            (t.name.clone(), (t.shape.clone(), vals))
        })
        .collect()
}

struct RefForward<'a> {
    w: &'a HashMap<String, (Vec<usize>, Vec<f64>)>,
    cfg: ModelConfig,
}

impl RefForward<'_> {
    fn get(&self, name: &str) -> (&[usize], &[f64]) {
        let (shape, vals) = self.w.get(name).unwrap_or_else(|| panic!("missing {name}"));
        (shape.as_slice(), vals.as_slice())
    }

    fn blk(&self, li: usize, stem: &str) -> (&[usize], &[f64]) {
        self.get(&format!("blk.{li}.{stem}.weight"))
    }

    fn matvec(&self, (shape, vals): (&[usize], &[f64]), x: &[f64]) -> Vec<f64> {
        let n = *shape.last().unwrap();
        assert_eq!(n, x.len());
        vals.chunks_exact(n)
            .map(|row| row.iter().zip(x).map(|(&a, &b)| a * b).sum::<f64>())
            .collect()
    }

    fn norm(&self, x: &[f64], g: &[f64]) -> Vec<f64> {
        let ms = x.iter().map(|v| v * v).sum::<f64>() / x.len() as f64;
        let s = 1.0 / (ms + 1e-6).sqrt();
        x.iter().zip(g).map(|(&v, &gv)| v * s * gv).collect()
    }

    fn rope(&self, x: &mut [f64], pos: usize) {
        let d = self.cfg.qk_rope_head_dim as f64;
        for i in 0..x.len() / 2 {
            let ang = pos as f64 * 10000f64.powf(-(2 * i) as f64 / d);
            let (s, c) = ang.sin_cos();
            let (a, b) = (x[2 * i], x[2 * i + 1]);
            x[2 * i] = a * c - b * s;
            x[2 * i + 1] = a * s + b * c;
        }
    }

    fn softmax(&self, x: &mut [f64]) {
        let m = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut s = 0.0;
        for v in x.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in x.iter_mut() {
            *v /= s;
        }
    }

    fn mlp(&self, li: usize, stems: [&str; 3], x: &[f64], expert: Option<usize>) -> Vec<f64> {
        let slice = |(shape, vals): (&[usize], &[f64])| -> (Vec<usize>, Vec<f64>) {
            match expert {
                None => (shape.to_vec(), vals.to_vec()),
                Some(e) => {
                    let per = shape[1] * shape[2];
                    (vec![shape[1], shape[2]], vals[e * per..(e + 1) * per].to_vec())
                }
            }
        };
        let (gs, gv) = slice(self.blk(li, stems[0]));
        let (us, uv) = slice(self.blk(li, stems[1]));
        let (ds, dv) = slice(self.blk(li, stems[2]));
        let g = self.matvec((&gs, &gv), x);
        let u = self.matvec((&us, &uv), x);
        let a: Vec<f64> = g
            .iter()
            .zip(&u)
            .map(|(&gv, &uv)| gv / (1.0 + (-gv).exp()) * uv)
            .collect();
        self.matvec((&ds, &dv), &a)
    }

    /// Forward `tokens`, returning logits rows for every position at or
    /// past `want_from`.
    fn run(&self, tokens: &[i32], want_from: usize) -> Vec<Vec<f64>> {
        let cfg = &self.cfg;
        let (nope, vh) = (cfg.qk_nope_head_dim, cfg.v_head_dim);
        let (qk_head, kv_rank) = (cfg.qk_head_dim(), cfg.kv_lora_rank);
        let mut caches: Vec<Vec<Vec<f64>>> = vec![Vec::new(); cfg.n_layers];
        let mut rows = Vec::new();
        for (pos, &tok) in tokens.iter().enumerate() {
            let (es, ev) = self.get("token_embd.weight");
            let t = tok.rem_euclid(es[0] as i32) as usize;
            let mut h: Vec<f64> = ev[t * es[1]..(t + 1) * es[1]].to_vec();
            for li in 0..cfg.n_layers {
                let xn = self.norm(&h, self.blk(li, "attn_norm").1);
                let q_a = self.matvec(self.blk(li, "attn_q_a"), &xn);
                let q_an = self.norm(&q_a, self.blk(li, "attn_q_a_norm").1);
                let q = self.matvec(self.blk(li, "attn_q_b"), &q_an);
                let kv_a = self.matvec(self.blk(li, "attn_kv_a_mqa"), &xn);
                let mut row = self.norm(&kv_a[..kv_rank], self.blk(li, "attn_kv_a_norm").1);
                let mut k_rope = kv_a[kv_rank..].to_vec();
                self.rope(&mut k_rope, pos);
                row.extend_from_slice(&k_rope);
                caches[li].push(row);
                let ctx = pos + 1;
                let kvb: Vec<Vec<f64>> = (0..ctx)
                    .map(|p| self.matvec(self.blk(li, "attn_kv_b"), &caches[li][p][..kv_rank]))
                    .collect();
                let mut heads = vec![0f64; cfg.n_heads * vh];
                for hd in 0..cfg.n_heads {
                    let mut qh = q[hd * qk_head..(hd + 1) * qk_head].to_vec();
                    let (q_nope, q_rope) = qh.split_at_mut(nope);
                    self.rope(q_rope, pos);
                    let mut sc: Vec<f64> = (0..ctx)
                        .map(|p| {
                            let kn = &kvb[p][hd * (nope + vh)..hd * (nope + vh) + nope];
                            let kr = &caches[li][p][kv_rank..];
                            let s = q_nope.iter().zip(kn).map(|(&a, &b)| a * b).sum::<f64>()
                                + q_rope.iter().zip(kr).map(|(&a, &b)| a * b).sum::<f64>();
                            s / (qk_head as f64).sqrt()
                        })
                        .collect();
                    self.softmax(&mut sc);
                    for (p, &w) in sc.iter().enumerate() {
                        let v = &kvb[p][hd * (nope + vh) + nope..hd * (nope + vh) + nope + vh];
                        for (o, &vv) in heads[hd * vh..(hd + 1) * vh].iter_mut().zip(v) {
                            *o += w * vv;
                        }
                    }
                }
                let attn = self.matvec(self.blk(li, "attn_output"), &heads);
                for (hv, av) in h.iter_mut().zip(&attn) {
                    *hv += av;
                }
                let xn = self.norm(&h, self.blk(li, "ffn_norm").1);
                let ffn = if !cfg.is_moe_layer(li) {
                    self.mlp(li, ["ffn_gate", "ffn_up", "ffn_down"], &xn, None)
                } else {
                    let mut probs = self.matvec(self.blk(li, "ffn_gate_inp"), &xn);
                    self.softmax(&mut probs);
                    let mut idx: Vec<usize> = (0..cfg.n_routed_experts).collect();
                    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap().then(a.cmp(&b)));
                    idx.truncate(cfg.n_active_experts);
                    idx.sort_unstable();
                    let z: f64 = idx.iter().map(|&e| probs[e]).sum();
                    let sh = ["ffn_gate_shexp", "ffn_up_shexp", "ffn_down_shexp"];
                    let mut out = self.mlp(li, sh, &xn, None);
                    for &e in &idx {
                        let y = self.mlp(
                            li,
                            ["ffn_gate_exps", "ffn_up_exps", "ffn_down_exps"],
                            &xn,
                            Some(e),
                        );
                        for (o, yv) in out.iter_mut().zip(&y) {
                            *o += probs[e] / z * yv;
                        }
                    }
                    out
                };
                for (hv, fv) in h.iter_mut().zip(&ffn) {
                    *hv += fv;
                }
            }
            if pos >= want_from {
                let xn = self.norm(&h, self.get("output_norm.weight").1);
                rows.push(self.matvec(self.get("output.weight"), &xn));
            }
        }
        rows
    }
}

fn rel_l2(a: &[f32], b: &[f64]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64 - y) * (x as f64 - y)).sum();
    let den: f64 = b.iter().map(|&y| y * y).sum();
    (num / den.max(1e-30)).sqrt()
}

/// The differential lock: the engine's quantized forward vs the f64
/// reference on the same decoded weights (arithmetic-order differences
/// only — measured ~2e-7) and vs the reference on the f32 source
/// weights (quantization error — measured rel-L2 ≈ 0.11 for DQ3_K_M,
/// ≈ 0.12 for Q4_K_M on this fixture; bounded per scheme).
#[test]
fn quantized_forward_tracks_f32_reference_within_per_format_tolerance() {
    let src_weights = decode_all(&golden_src());
    for (scheme, qtol) in [("dq3_k_m", 0.35), ("q4_k_m", 0.35)] {
        let fwd = forward(scheme, 1);
        let rows = run_script(&fwd);
        // The exact token sequence the engine ran (prompt + its greedy
        // choices), replayed through the references.
        let mut toks: Vec<i32> = PROMPT.to_vec();
        for r in &rows[..DECODE_STEPS] {
            toks.push(argmax(r));
        }
        let want_from = PROMPT.len() - 1;

        let qc = Container::from_bytes(qbytes(scheme).to_vec()).unwrap();
        let q_weights = decode_all(&qc);
        let same = RefForward { w: &q_weights, cfg: ModelConfig::tiny_moe() }
            .run(&toks, want_from);
        assert_eq!(same.len(), rows.len());
        for (i, (got, want)) in rows.iter().zip(&same).enumerate() {
            let d = rel_l2(got, want);
            assert!(d < 1e-4, "{scheme} row {i}: engine vs same-weights f64 reference {d:.2e}");
        }

        let srcref = RefForward { w: &src_weights, cfg: ModelConfig::tiny_moe() }
            .run(&toks, want_from);
        let worst = rows
            .iter()
            .zip(&srcref)
            .map(|(got, want)| rel_l2(got, want))
            .fold(0.0f64, f64::max);
        assert!(
            worst < qtol,
            "{scheme}: quantized logits drift {worst:.3} exceeds per-scheme tolerance {qtol}"
        );
        assert!(
            worst > 1e-4,
            "{scheme}: quantization should measurably perturb logits (got {worst:.2e})"
        );
    }
}
