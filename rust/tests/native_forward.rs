//! Differential + property suite for the native forward pass
//! (`runtime::forward`), the computation behind `dsq eval --native` —
//! covering **both architecture families**: the MLA+MoE step (tiny-moe,
//! Tables 2–4) and the dense-GQA step of the distill shapes
//! (tiny-dense, Table 5).
//!
//! Five locks, mirroring the codec golden suite one level up:
//!
//! 1. **Golden logits** — the shared script (prefill [`PROMPT`] on the
//!    seed-`0x601D` container, then greedy decode) must hash to the
//!    committed `tests/golden/forward.*.fnv64` (tiny-moe) and
//!    `forward.tiny_dense.*.fnv64` checksums for the DQ3_K_M and
//!    Q4_K_M schemes. The committed fixtures were produced by the
//!    bit-exact Python mirror in `python/tools/bless_goldens.py`, so
//!    this test is also the Rust↔Python cross-language gate.
//! 2. **Differential vs an in-test f64 reference** — an independent
//!    plain-loop float64 forward (libm transcendentals, natural-order
//!    sums, no shared code with the engine) must agree to ~1e-4 on the
//!    *same* decoded weights, and within the per-scheme quantization
//!    tolerance on the f32 *source* weights.
//! 3. **Bit identity** — logits are identical across matvec thread
//!    counts {1, 2, 8}, across every available pinned dispatch arm
//!    (scalar, lanes, AVX2/NEON simd), across panel-GEMM vs per-token
//!    prefill, and across absorbed vs eager MLA; CI reruns this whole
//!    suite with `DSQ_FORCE_ARM` pinned to each arm so the
//!    env-selected path is held to the same fixtures.
//! 4. **KV-cache coherence** — incremental decode (logits requested at
//!    every step) is bit-identical to a fresh full prefill of the same
//!    token prefix, and attention state actually matters (the same
//!    token at different positions produces different logits).
//! 5. **Allocation discipline** — `forward_token` performs zero heap
//!    allocations per decoded token and panel prefill none beyond the
//!    cache's lazy KV buffers (counted by the test binary's global
//!    allocator), scratch reuse does not perturb logits, and untouched
//!    KV caches never allocate their backing buffer.

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::sampler::argmax;
use dsq::model::{ModelConfig, ModelKind};
use dsq::quant::kernels::DispatchArm;
use dsq::runtime::forward::{ForwardPass, MatvecMode};
use dsq::runtime::native::NATIVE_MAX_CTX;
use dsq::util::fnv64;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::OnceLock;

// --- counting allocator (lock 5) -----------------------------------------
//
// Counts allocation *events* per thread; matvecs run in
// `MatvecMode::Threads(1)` during the zero-alloc assertion, so the
// measuring thread sees every allocation the decode loop makes. The
// counter is thread-local (const-initialized — no lazy TLS allocation
// inside the allocator), so concurrently running tests in this binary
// don't perturb the measurement.

use std::alloc::{GlobalAlloc, Layout, System};

struct CountingAlloc;

thread_local! {
    static THREAD_ALLOCS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(p, l, n)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// --- shared fixtures ------------------------------------------------------

/// The golden script, mirrored verbatim by `bless_goldens.py`.
const PROMPT: [i32; 8] = [1, 17, 300, 42, 511, 7, 5, 260];
const DECODE_STEPS: usize = 4;

/// Both tiny proxies ride the same suite.
const MODELS: [&str; 2] = ["tiny-moe", "tiny-dense"];

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn golden_src(model: &str) -> Container {
    synthetic_f32_container(&ModelConfig::by_name(model).unwrap(), 0x601D).unwrap()
}

/// Fixture file for a (model, scheme) pair — tiny-moe keeps its PR-4
/// names, the dense fixtures carry the model in the name.
fn fixture_name(model: &str, scheme: &str) -> String {
    match model {
        "tiny-moe" => format!("forward.{scheme}.fnv64"),
        "tiny-dense" => format!("forward.tiny_dense.{scheme}.fnv64"),
        other => panic!("unexpected model {other}"),
    }
}

/// Quantized golden-container bytes, built once per (model, scheme).
fn qbytes(model: &str, scheme: &str) -> &'static [u8] {
    static MOE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static MOE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    let cell = match (model, scheme) {
        ("tiny-moe", "dq3_k_m") => &MOE_DQ3,
        ("tiny-moe", "q4_k_m") => &MOE_Q4,
        ("tiny-dense", "dq3_k_m") => &DENSE_DQ3,
        ("tiny-dense", "q4_k_m") => &DENSE_Q4,
        other => panic!("unexpected combination {other:?}"),
    };
    cell.get_or_init(|| {
        let scheme = dsq::scheme::builtin::scheme(scheme).unwrap();
        quantize_container_with(&golden_src(model), &scheme, None, 1).unwrap().to_bytes()
    })
}

fn forward(model: &str, scheme: &str, threads: usize) -> ForwardPass {
    let ckpt = Container::from_bytes(qbytes(model, scheme).to_vec()).unwrap();
    ForwardPass::new(ckpt, threads, NATIVE_MAX_CTX).unwrap()
}

/// Run the golden script: prefill `PROMPT` (logits at the last prompt
/// token only), then `DECODE_STEPS` greedy steps. Returns the emitted
/// logits rows (1 + DECODE_STEPS of them).
fn run_script(fwd: &ForwardPass) -> Vec<Vec<f32>> {
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    let mut logits = vec![0f32; fwd.vocab()];
    for (j, &t) in PROMPT.iter().enumerate() {
        let want = if j + 1 == PROMPT.len() { Some(&mut logits[..]) } else { None };
        fwd.forward_token(t, &mut cache, &mut scratch, want).unwrap();
    }
    let mut rows = vec![logits.clone()];
    for _ in 0..DECODE_STEPS {
        let tok = argmax(rows.last().unwrap());
        fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        rows.push(logits.clone());
    }
    rows
}

fn bits(rows: &[Vec<f32>]) -> Vec<u32> {
    rows.iter().flatten().map(|v| v.to_bits()).collect()
}

fn slice_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn golden_forward_logits_checksums() {
    for model in MODELS {
        for scheme in ["dq3_k_m", "q4_k_m"] {
            let rows = run_script(&forward(model, scheme, 1));
            let mut blob = Vec::with_capacity(rows.len() * rows[0].len() * 4);
            for r in &rows {
                for v in r {
                    blob.extend_from_slice(&v.to_le_bytes());
                }
            }
            let line = format!("{:016x} {}\n", fnv64(&blob), blob.len());
            let path = golden_dir().join(fixture_name(model, scheme));
            if !path.exists() {
                std::fs::write(&path, &line).unwrap();
                eprintln!("[golden] blessed new fixture {} — commit it", path.display());
                continue;
            }
            let expect = std::fs::read_to_string(&path).unwrap();
            assert_eq!(
                expect.trim(),
                line.trim(),
                "forward logits for {model}/{scheme} drifted from {}; if the change is \
                 intentional, re-bless from python/tools/bless_goldens.py (or delete + rerun) \
                 and call it out in the PR",
                path.display()
            );
        }
    }
}

#[test]
fn logits_bit_identical_across_threads_and_dispatch_arms() {
    for model in MODELS {
        let base = bits(&run_script(&forward(model, "dq3_k_m", 1)));
        let mut modes = vec![
            ("threads=2".to_string(), MatvecMode::Threads(2)),
            ("threads=8".to_string(), MatvecMode::Threads(8)),
        ];
        for arm in DispatchArm::ALL {
            if arm.available() {
                modes.push((format!("pinned {} arm", arm.name()), MatvecMode::Pinned(arm)));
            }
        }
        for (label, mode) in modes {
            let mut fwd = forward(model, "dq3_k_m", 1);
            fwd.set_mode(mode);
            assert_eq!(base, bits(&run_script(&fwd)), "{model}: {label}");
        }
    }
}

/// The panel-prefill lock: running the whole prompt as one quantized
/// GEMM panel (`forward_tokens`) is bit-identical to the per-token
/// loop — logits, the latent/K-V cache plane, and (for absorbed MLA)
/// the expanded-KV plane — and decode continues identically off either
/// cache.
#[test]
fn panel_prefill_matches_token_loop_bitwise() {
    for model in MODELS {
        for scheme in ["dq3_k_m", "q4_k_m"] {
            let fwd = forward(model, scheme, 2);
            // Per-token loop.
            let mut c1 = fwd.new_cache();
            let mut s1 = fwd.new_scratch();
            let mut l1 = vec![0f32; fwd.vocab()];
            for (j, &t) in PROMPT.iter().enumerate() {
                let want = if j + 1 == PROMPT.len() { Some(&mut l1[..]) } else { None };
                fwd.forward_token(t, &mut c1, &mut s1, want).unwrap();
            }
            // One GEMM panel over the same prompt.
            let mut c2 = fwd.new_cache();
            let mut s2 = fwd.new_scratch();
            let mut l2 = vec![0f32; fwd.vocab()];
            fwd.forward_tokens(&PROMPT, &mut c2, &mut s2, Some(&mut l2)).unwrap();
            assert_eq!(c2.len(), PROMPT.len(), "{model}/{scheme}: panel cache length");
            assert_eq!(slice_bits(&l1), slice_bits(&l2), "{model}/{scheme}: prefill logits");
            assert_eq!(
                slice_bits(c1.raw_rows()),
                slice_bits(c2.raw_rows()),
                "{model}/{scheme}: latent/K-V cache plane"
            );
            assert_eq!(
                slice_bits(c1.raw_expanded()),
                slice_bits(c2.raw_expanded()),
                "{model}/{scheme}: expanded-KV plane"
            );
            // Greedy decode continues identically off either cache.
            let tok = argmax(&l1);
            fwd.forward_token(tok, &mut c1, &mut s1, Some(&mut l1)).unwrap();
            fwd.forward_token(tok, &mut c2, &mut s2, Some(&mut l2)).unwrap();
            assert_eq!(slice_bits(&l1), slice_bits(&l2), "{model}/{scheme}: decode after prefill");
        }
    }
}

/// The absorption seam: eager per-step latent re-expansion
/// (`set_mla_absorption(false)`, the pre-PR-6 decode shape) lands on
/// the same bits as the default absorbed path that the committed
/// goldens pin — so the absorbed rewrite changed arithmetic cost, not
/// arithmetic. Dense-GQA models ignore the toggle; they ride along to
/// lock that.
#[test]
fn eager_mla_matches_absorbed_default() {
    for model in MODELS {
        let base = bits(&run_script(&forward(model, "dq3_k_m", 1)));
        let mut fwd = forward(model, "dq3_k_m", 1);
        fwd.set_mla_absorption(false);
        assert_eq!(base, bits(&run_script(&fwd)), "{model}: eager vs absorbed MLA");
    }
}

#[test]
fn incremental_decode_equals_full_prefill() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 2);
        let toks = [1i32, 9, 300, 42, 77, 5];
        // Incremental: one cache, logits requested at every step.
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        let mut logits = vec![0f32; fwd.vocab()];
        let mut per_step: Vec<Vec<u32>> = Vec::new();
        for &t in &toks {
            fwd.forward_token(t, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
            per_step.push(logits.iter().map(|v| v.to_bits()).collect());
        }
        // Fresh prefills of each prefix (logits only at the final token)
        // must land on the same bits: requesting logits mid-stream does
        // not perturb the cache, and the cache replays exactly.
        for k in [1usize, 3, 6] {
            let mut c2 = fwd.new_cache();
            let mut s2 = fwd.new_scratch();
            for (j, &t) in toks[..k].iter().enumerate() {
                let want = if j + 1 == k { Some(&mut logits[..]) } else { None };
                fwd.forward_token(t, &mut c2, &mut s2, want).unwrap();
            }
            let got: Vec<u32> = logits.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, per_step[k - 1], "{model}: prefix length {k}");
            assert_eq!(c2.len(), k);
        }
    }
}

#[test]
fn attention_state_makes_positions_distinct() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 1);
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        let mut first = vec![0f32; fwd.vocab()];
        let mut second = vec![0f32; fwd.vocab()];
        fwd.forward_token(42, &mut cache, &mut scratch, Some(&mut first)).unwrap();
        fwd.forward_token(42, &mut cache, &mut scratch, Some(&mut second)).unwrap();
        assert_ne!(
            bits(&[first]),
            bits(&[second]),
            "{model}: same token at positions 0 and 1 must see different attention state"
        );
    }
}

/// The scratch-reuse lock: a scratch recycled across every token (the
/// serving configuration) produces the same bits as a freshly allocated
/// scratch per token — i.e. no intermediate leaks across steps. The
/// committed moe goldens additionally pin that the scratch refactor
/// changed nothing relative to the PR-4 allocate-per-call code.
#[test]
fn fresh_and_reused_scratch_produce_identical_logits() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 1);
        let toks = [3i32, 150, 42, 509, 8];
        let mut reused = fwd.new_scratch();
        let mut cache_a = fwd.new_cache();
        let mut cache_b = fwd.new_cache();
        let mut la = vec![0f32; fwd.vocab()];
        let mut lb = vec![0f32; fwd.vocab()];
        for &t in &toks {
            fwd.forward_token(t, &mut cache_a, &mut reused, Some(&mut la)).unwrap();
            let mut fresh = fwd.new_scratch();
            fwd.forward_token(t, &mut cache_b, &mut fresh, Some(&mut lb)).unwrap();
            assert_eq!(
                la.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                lb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{model}: reused scratch diverged at token {t}"
            );
        }
    }
}

/// The acceptance lock for the per-token allocation defect: after the
/// cache's lazy KV buffer exists, a decoded token touches the heap
/// exactly zero times — for both architectures, logits included.
#[test]
fn forward_token_decode_is_allocation_free() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 1);
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        let mut logits = vec![0f32; fwd.vocab()];
        // Warm up: the first token forces the cache's lazy allocation
        // (and the dispatch arm's one-time env lookup).
        fwd.forward_token(1, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        let before = thread_allocs();
        for t in [17i32, 300, 42] {
            fwd.forward_token(t, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        }
        let allocs = thread_allocs() - before;
        assert_eq!(allocs, 0, "{model}: decode made {allocs} heap allocations in 3 tokens");
    }
}

/// The panel-prefill allocation bound: after one warm-up wave, a whole
/// prompt pushed through `forward_tokens` may only allocate the target
/// cache's own lazy buffers — the latent/K-V plane plus, for absorbed
/// MLA, the expanded-KV plane (≤ 2 allocation events). On a cache whose
/// buffers already exist the wave is allocation-free: every panel lives
/// in the reused scratch.
#[test]
fn panel_prefill_allocations_bounded_per_wave() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 1);
        let mut scratch = fwd.new_scratch();
        let mut logits = vec![0f32; fwd.vocab()];
        // Warm up: first wave pays one-time costs (dispatch-arm env
        // lookup) besides its own cache allocation.
        let mut warm = fwd.new_cache();
        fwd.forward_tokens(&PROMPT, &mut warm, &mut scratch, Some(&mut logits)).unwrap();
        // Fresh cache: only the lazy cache buffers may allocate.
        let mut cache = fwd.new_cache();
        let before = thread_allocs();
        fwd.forward_tokens(&PROMPT, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        let allocs = thread_allocs() - before;
        assert!(
            allocs <= 2,
            "{model}: panel prefill made {allocs} heap allocations beyond the lazy cache buffers"
        );
        // Allocated cache (the warm one still has room): zero allocs.
        let before = thread_allocs();
        fwd.forward_tokens(&PROMPT, &mut warm, &mut scratch, Some(&mut logits)).unwrap();
        let allocs = thread_allocs() - before;
        assert_eq!(allocs, 0, "{model}: panel prefill on an allocated cache made {allocs} allocs");
    }
}

#[test]
fn untouched_caches_never_allocate() {
    let fwd = forward("tiny-dense", "q4_k_m", 1);
    let cache = fwd.new_cache();
    assert!(!cache.is_allocated(), "fresh cache must not allocate eagerly");
    drop(cache);
    // And the first token allocates exactly once (the KV buffer).
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    fwd.forward_token(1, &mut cache, &mut scratch, None).unwrap();
    assert!(cache.is_allocated());
}

// --- the independent f64 reference forward -------------------------------

/// Every tensor of a container decoded to f64 (shape kept).
fn decode_all(c: &Container) -> HashMap<String, (Vec<usize>, Vec<f64>)> {
    c.tensors
        .iter()
        .map(|t| {
            let vals: Vec<f64> = c.dequantize(t).unwrap().iter().map(|&v| v as f64).collect();
            (t.name.clone(), (t.shape.clone(), vals))
        })
        .collect()
}

struct RefForward<'a> {
    w: &'a HashMap<String, (Vec<usize>, Vec<f64>)>,
    cfg: ModelConfig,
}

impl RefForward<'_> {
    fn get(&self, name: &str) -> (&[usize], &[f64]) {
        let (shape, vals) = self.w.get(name).unwrap_or_else(|| panic!("missing {name}"));
        (shape.as_slice(), vals.as_slice())
    }

    fn blk(&self, li: usize, stem: &str) -> (&[usize], &[f64]) {
        self.get(&format!("blk.{li}.{stem}.weight"))
    }

    fn matvec(&self, (shape, vals): (&[usize], &[f64]), x: &[f64]) -> Vec<f64> {
        let n = *shape.last().unwrap();
        assert_eq!(n, x.len());
        vals.chunks_exact(n)
            .map(|row| row.iter().zip(x).map(|(&a, &b)| a * b).sum::<f64>())
            .collect()
    }

    fn norm(&self, x: &[f64], g: &[f64]) -> Vec<f64> {
        let ms = x.iter().map(|v| v * v).sum::<f64>() / x.len() as f64;
        let s = 1.0 / (ms + 1e-6).sqrt();
        x.iter().zip(g).map(|(&v, &gv)| v * s * gv).collect()
    }

    /// Rotate half-split pairs `(x[i], x[i+half])` with
    /// `θ_i = rope_base^(−2i/d)` — `d` is the rotated span (rope head
    /// dim for MLA, full head dim for GQA). Matches the HF/llama.cpp
    /// NeoX pairing used by `python/compile/model.py` and the runtime.
    fn rope(&self, x: &mut [f64], pos: usize, d: usize) {
        let half = x.len() / 2;
        for i in 0..half {
            let ang = pos as f64 * self.cfg.rope_base.powf(-(2 * i) as f64 / d as f64);
            let (s, c) = ang.sin_cos();
            let (a, b) = (x[i], x[i + half]);
            x[i] = a * c - b * s;
            x[i + half] = a * s + b * c;
        }
    }

    fn softmax(&self, x: &mut [f64]) {
        let m = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut s = 0.0;
        for v in x.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in x.iter_mut() {
            *v /= s;
        }
    }

    fn mlp(&self, li: usize, stems: [&str; 3], x: &[f64], expert: Option<usize>) -> Vec<f64> {
        let slice = |(shape, vals): (&[usize], &[f64])| -> (Vec<usize>, Vec<f64>) {
            match expert {
                None => (shape.to_vec(), vals.to_vec()),
                Some(e) => {
                    let per = shape[1] * shape[2];
                    (vec![shape[1], shape[2]], vals[e * per..(e + 1) * per].to_vec())
                }
            }
        };
        let (gs, gv) = slice(self.blk(li, stems[0]));
        let (us, uv) = slice(self.blk(li, stems[1]));
        let (ds, dv) = slice(self.blk(li, stems[2]));
        let g = self.matvec((&gs, &gv), x);
        let u = self.matvec((&us, &uv), x);
        let a: Vec<f64> = g
            .iter()
            .zip(&u)
            .map(|(&gv, &uv)| gv / (1.0 + (-gv).exp()) * uv)
            .collect();
        self.matvec((&ds, &dv), &a)
    }

    /// One layer of MLA attention over the per-layer latent cache.
    fn attention_mla(
        &self,
        li: usize,
        xn: &[f64],
        cache: &mut Vec<Vec<f64>>,
        pos: usize,
    ) -> Vec<f64> {
        let cfg = &self.cfg;
        let (nope, vh) = (cfg.qk_nope_head_dim, cfg.v_head_dim);
        let (qk_head, kv_rank) = (cfg.qk_head_dim(), cfg.kv_lora_rank);
        let rope_d = cfg.qk_rope_head_dim;
        let q_a = self.matvec(self.blk(li, "attn_q_a"), xn);
        let q_an = self.norm(&q_a, self.blk(li, "attn_q_a_norm").1);
        let q = self.matvec(self.blk(li, "attn_q_b"), &q_an);
        let kv_a = self.matvec(self.blk(li, "attn_kv_a_mqa"), xn);
        let mut row = self.norm(&kv_a[..kv_rank], self.blk(li, "attn_kv_a_norm").1);
        let mut k_rope = kv_a[kv_rank..].to_vec();
        self.rope(&mut k_rope, pos, rope_d);
        row.extend_from_slice(&k_rope);
        cache.push(row);
        let ctx = pos + 1;
        let kvb: Vec<Vec<f64>> = (0..ctx)
            .map(|p| self.matvec(self.blk(li, "attn_kv_b"), &cache[p][..kv_rank]))
            .collect();
        let mut heads = vec![0f64; cfg.n_heads * vh];
        for hd in 0..cfg.n_heads {
            let mut qh = q[hd * qk_head..(hd + 1) * qk_head].to_vec();
            let (q_nope, q_rope) = qh.split_at_mut(nope);
            self.rope(q_rope, pos, rope_d);
            let mut sc: Vec<f64> = (0..ctx)
                .map(|p| {
                    let kn = &kvb[p][hd * (nope + vh)..hd * (nope + vh) + nope];
                    let kr = &cache[p][kv_rank..];
                    let s = q_nope.iter().zip(kn).map(|(&a, &b)| a * b).sum::<f64>()
                        + q_rope.iter().zip(kr).map(|(&a, &b)| a * b).sum::<f64>();
                    s / (qk_head as f64).sqrt()
                })
                .collect();
            self.softmax(&mut sc);
            for (p, &w) in sc.iter().enumerate() {
                let v = &kvb[p][hd * (nope + vh) + nope..hd * (nope + vh) + nope + vh];
                for (o, &vv) in heads[hd * vh..(hd + 1) * vh].iter_mut().zip(v) {
                    *o += w * vv;
                }
            }
        }
        self.matvec(self.blk(li, "attn_output"), &heads)
    }

    /// One layer of grouped-query attention over a conventional
    /// per-head K/V cache (rows of `[post-RoPE K | V]`).
    fn attention_gqa(
        &self,
        li: usize,
        xn: &[f64],
        cache: &mut Vec<Vec<f64>>,
        pos: usize,
    ) -> Vec<f64> {
        let cfg = &self.cfg;
        let hd = cfg.head_dim;
        let kd = cfg.n_kv_heads * hd;
        let group = cfg.n_heads / cfg.n_kv_heads;
        let q = self.matvec(self.blk(li, "attn_q"), xn);
        let mut k = self.matvec(self.blk(li, "attn_k"), xn);
        let v = self.matvec(self.blk(li, "attn_v"), xn);
        for kh in 0..cfg.n_kv_heads {
            self.rope(&mut k[kh * hd..(kh + 1) * hd], pos, hd);
        }
        k.extend_from_slice(&v);
        cache.push(k);
        let ctx = pos + 1;
        let mut heads = vec![0f64; cfg.n_heads * hd];
        for head in 0..cfg.n_heads {
            let mut qh = q[head * hd..(head + 1) * hd].to_vec();
            self.rope(&mut qh, pos, hd);
            let kh = head / group;
            let mut sc: Vec<f64> = (0..ctx)
                .map(|p| {
                    let kr = &cache[p][kh * hd..(kh + 1) * hd];
                    qh.iter().zip(kr).map(|(&a, &b)| a * b).sum::<f64>() / (hd as f64).sqrt()
                })
                .collect();
            self.softmax(&mut sc);
            for (p, &w) in sc.iter().enumerate() {
                let vr = &cache[p][kd + kh * hd..kd + (kh + 1) * hd];
                for (o, &vv) in heads[head * hd..(head + 1) * hd].iter_mut().zip(vr) {
                    *o += w * vv;
                }
            }
        }
        self.matvec(self.blk(li, "attn_output"), &heads)
    }

    /// Forward `tokens`, returning logits rows for every position at or
    /// past `want_from`.
    fn run(&self, tokens: &[i32], want_from: usize) -> Vec<Vec<f64>> {
        let cfg = &self.cfg;
        let mut caches: Vec<Vec<Vec<f64>>> = vec![Vec::new(); cfg.n_layers];
        let mut rows = Vec::new();
        for (pos, &tok) in tokens.iter().enumerate() {
            let (es, ev) = self.get("token_embd.weight");
            let t = tok.rem_euclid(es[0] as i32) as usize;
            let mut h: Vec<f64> = ev[t * es[1]..(t + 1) * es[1]].to_vec();
            for li in 0..cfg.n_layers {
                let xn = self.norm(&h, self.blk(li, "attn_norm").1);
                let attn = match cfg.kind {
                    ModelKind::MlaMoe => self.attention_mla(li, &xn, &mut caches[li], pos),
                    ModelKind::DenseGqa => self.attention_gqa(li, &xn, &mut caches[li], pos),
                };
                for (hv, av) in h.iter_mut().zip(&attn) {
                    *hv += av;
                }
                let xn = self.norm(&h, self.blk(li, "ffn_norm").1);
                let ffn = if !cfg.is_moe_layer(li) {
                    self.mlp(li, ["ffn_gate", "ffn_up", "ffn_down"], &xn, None)
                } else {
                    let mut probs = self.matvec(self.blk(li, "ffn_gate_inp"), &xn);
                    self.softmax(&mut probs);
                    let mut idx: Vec<usize> = (0..cfg.n_routed_experts).collect();
                    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap().then(a.cmp(&b)));
                    idx.truncate(cfg.n_active_experts);
                    idx.sort_unstable();
                    let z: f64 = idx.iter().map(|&e| probs[e]).sum();
                    let sh = ["ffn_gate_shexp", "ffn_up_shexp", "ffn_down_shexp"];
                    let mut out = self.mlp(li, sh, &xn, None);
                    for &e in &idx {
                        let y = self.mlp(
                            li,
                            ["ffn_gate_exps", "ffn_up_exps", "ffn_down_exps"],
                            &xn,
                            Some(e),
                        );
                        for (o, yv) in out.iter_mut().zip(&y) {
                            *o += probs[e] / z * yv;
                        }
                    }
                    out
                };
                for (hv, fv) in h.iter_mut().zip(&ffn) {
                    *hv += fv;
                }
            }
            if pos >= want_from {
                let xn = self.norm(&h, self.get("output_norm.weight").1);
                rows.push(self.matvec(self.get("output.weight"), &xn));
            }
        }
        rows
    }
}

fn rel_l2(a: &[f32], b: &[f64]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64 - y) * (x as f64 - y)).sum();
    let den: f64 = b.iter().map(|&y| y * y).sum();
    (num / den.max(1e-30)).sqrt()
}

/// The differential lock, for both model kinds: the engine's quantized
/// forward vs the f64 reference on the same decoded weights
/// (arithmetic-order differences only — measured ~2e-7) and vs the
/// reference on the f32 source weights (quantization error — measured
/// rel-L2 ≈ 0.11–0.13 on these fixtures; bounded per scheme).
#[test]
fn quantized_forward_tracks_f32_reference_within_per_format_tolerance() {
    for model in MODELS {
        let cfg = ModelConfig::by_name(model).unwrap();
        let src_weights = decode_all(&golden_src(model));
        for (scheme, qtol) in [("dq3_k_m", 0.35), ("q4_k_m", 0.35)] {
            let fwd = forward(model, scheme, 1);
            let rows = run_script(&fwd);
            // The exact token sequence the engine ran (prompt + its
            // greedy choices), replayed through the references.
            let mut toks: Vec<i32> = PROMPT.to_vec();
            for r in &rows[..DECODE_STEPS] {
                toks.push(argmax(r));
            }
            let want_from = PROMPT.len() - 1;

            let qc = Container::from_bytes(qbytes(model, scheme).to_vec()).unwrap();
            let q_weights = decode_all(&qc);
            let same = RefForward { w: &q_weights, cfg: cfg.clone() }.run(&toks, want_from);
            assert_eq!(same.len(), rows.len());
            for (i, (got, want)) in rows.iter().zip(&same).enumerate() {
                let d = rel_l2(got, want);
                assert!(
                    d < 1e-4,
                    "{model}/{scheme} row {i}: engine vs same-weights f64 reference {d:.2e}"
                );
            }

            let srcref = RefForward { w: &src_weights, cfg: cfg.clone() }.run(&toks, want_from);
            let worst = rows
                .iter()
                .zip(&srcref)
                .map(|(got, want)| rel_l2(got, want))
                .fold(0.0f64, f64::max);
            assert!(
                worst < qtol,
                "{model}/{scheme}: quantized logits drift {worst:.3} exceeds tolerance {qtol}"
            );
            assert!(
                worst > 1e-4,
                "{model}/{scheme}: quantization should measurably perturb logits \
                 (got {worst:.2e})"
            );
        }
    }
}
