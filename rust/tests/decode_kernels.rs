//! Decode-kernel property suite: the batch decoders and fused
//! `vec_dot` / `vec_dot_mat` kernels (`quant::kernels`) against their
//! scalar references, across dispatch arms and thread counts.
//!
//! The contract under test (see `quant/mod.rs` module docs):
//!
//! - `decode_blocks` is **bit-identical** across the scalar, lane and
//!   simd (AVX2/NEON) dispatch arms, at every thread count;
//! - `vec_dot(q, x)` equals `kernels::dot_lanes(decode_blocks(q), x)`
//!   bit-for-bit on every arm (fixed 8-lane reduction order, no FMA);
//! - `vec_dot_rows` is bit-identical at thread counts {1, 2, 8} and
//!   equals the per-row `vec_dot` loop;
//! - `vec_dot_mat` over a T-column panel equals T independent
//!   `vec_dot` calls bit-for-bit, per arm, for every panel width, and
//!   `vec_dot_rows_mat` is bit-identical at every thread count.
//!
//! The runtime dispatch itself (`DSQ_FORCE_ARM` /
//! `DSQ_SCALAR_DECODE`) is process-global, so cross-arm assertions go
//! through the pinned seams (`decode_blocks_arm` / `vec_dot_arm` /
//! `vec_dot_mat_arm`, plus the PR-3 bool-pinned wrappers); CI
//! additionally reruns the whole suite with `DSQ_FORCE_ARM` pinned to
//! each arm so the env-selected path is exercised everywhere too.
//! Arms unavailable on the host (`simd` without AVX2) are skipped —
//! `DispatchArm::available` gates each loop.

use dsq::quant::{self, kernels, BlockCodec, QuantFormat};
use dsq::util::rng::Pcg;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn seeded(fmt: QuantFormat, nblocks: usize, salt: u64) -> (Vec<f32>, Vec<u8>) {
    let n = fmt.block_weights() * nblocks;
    let mut rng = Pcg::new(salt ^ ((fmt.block_bytes() as u64) << 8) ^ nblocks as u64);
    let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let packed = quant::quantize(fmt, &data, None).unwrap();
    (data, packed)
}

#[test]
fn decode_arms_bit_identical_across_thread_counts() {
    for fmt in QuantFormat::ALL {
        for nblocks in [1usize, 4, 9] {
            let (data, packed) = seeded(fmt, nblocks, 0xDECD);
            let n = data.len();
            let mut fast = vec![0f32; n];
            let mut scalar = vec![0f32; n];
            kernels::decode_blocks_pinned(fmt, &packed, &mut fast, true);
            kernels::decode_blocks_pinned(fmt, &packed, &mut scalar, false);
            assert_eq!(bits(&fast), bits(&scalar), "{fmt} nblocks={nblocks} arms");
            // The dispatch-selected parallel path must land on the same
            // bits at every thread count.
            for threads in [1usize, 2, 8] {
                let mut out = vec![0f32; n];
                quant::dequantize_into_with(fmt, &packed, &mut out, threads).unwrap();
                assert_eq!(bits(&out), bits(&fast), "{fmt} nblocks={nblocks} threads={threads}");
            }
        }
    }
}

#[test]
fn vec_dot_matches_decode_then_dot_on_both_arms() {
    for fmt in QuantFormat::ALL {
        let (data, packed) = seeded(fmt, 5, 0xD07D);
        let n = data.len();
        let mut rng = Pcg::new(0xAC71 ^ fmt.block_bytes() as u64);
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let mut decoded = vec![0f32; n];
        kernels::decode_blocks_pinned(fmt, &packed, &mut decoded, false);
        let want = kernels::dot_lanes(&decoded, &x);
        for fast in [false, true] {
            let got = kernels::vec_dot_pinned(fmt, &packed, &x, fast);
            assert_eq!(got.to_bits(), want.to_bits(), "{fmt} fast={fast}");
        }
        // Public dispatch-selected entry point agrees too.
        let got = quant::vec_dot(fmt, &packed, &x).unwrap();
        assert_eq!(got.to_bits(), want.to_bits(), "{fmt} dispatch");
    }
}

#[test]
fn vec_dot_rows_bit_identical_across_thread_counts() {
    for fmt in QuantFormat::ALL {
        let rows = 13usize;
        let n = fmt.block_weights().max(64) * 2;
        let mut rng = Pcg::new(0x505 ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let packed = quant::quantize(fmt, &data, None).unwrap();
        let mut base = vec![0f32; rows];
        quant::vec_dot_rows_with(fmt, &packed, &x, &mut base, 1).unwrap();
        // Serial result is exactly the per-row fused dot.
        let rb = fmt.row_bytes(n).unwrap();
        for (r, row) in packed.chunks_exact(rb).enumerate() {
            let want = quant::vec_dot(fmt, row, &x).unwrap();
            assert_eq!(base[r].to_bits(), want.to_bits(), "{fmt} row {r}");
        }
        for threads in [2usize, 8] {
            let mut out = vec![0f32; rows];
            quant::vec_dot_rows_with(fmt, &packed, &x, &mut out, threads).unwrap();
            assert_eq!(bits(&out), bits(&base), "{fmt} threads={threads}");
        }
    }
}

#[test]
fn fused_matvec_equals_dequantize_then_matvec() {
    // The end-to-end identity the native serving backend relies on:
    // fused vec_dot_rows over encoded rows == decode the whole matrix,
    // then the canonical lane dot per row — bit for bit.
    for fmt in [QuantFormat::Q4K, QuantFormat::Q3K, QuantFormat::Q8_0] {
        let rows = 16usize;
        let n = 1024usize;
        let mut rng = Pcg::new(0xFA57 ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let packed = quant::quantize(fmt, &data, None).unwrap();
        let mut fused = vec![0f32; rows];
        quant::vec_dot_rows(fmt, &packed, &x, &mut fused).unwrap();
        let decoded = quant::dequantize(fmt, &packed, rows * n).unwrap();
        let reference: Vec<f32> = decoded
            .chunks_exact(n)
            .map(|row| kernels::dot_lanes(row, &x))
            .collect();
        assert_eq!(bits(&fused), bits(&reference), "{fmt}");
    }
}

fn available_arms() -> Vec<kernels::DispatchArm> {
    kernels::DispatchArm::ALL.into_iter().filter(|a| a.available()).collect()
}

#[test]
fn vec_dot_mat_equals_per_column_vec_dot_on_every_arm() {
    // The GEMM contract: decode-once panels reproduce T independent
    // single-column fused dots bit-for-bit — per arm, for every panel
    // width (1 = degenerate single column, 3/8 = partial MAT_COLS
    // chunks, 17 = a full chunk plus remainder).
    for fmt in QuantFormat::ALL {
        let (data, packed) = seeded(fmt, 5, 0x6E17);
        let n = data.len();
        let mut rng = Pcg::new(0x6E18 ^ fmt.block_bytes() as u64);
        for t in [1usize, 3, 8, 17] {
            let xs: Vec<f32> = (0..t * n).map(|_| rng.next_normal()).collect();
            let mut out = vec![0f32; t];
            for arm in available_arms() {
                kernels::vec_dot_mat_arm(fmt, &packed, &xs, n, &mut out, arm);
                for (c, &got) in out.iter().enumerate() {
                    let want = kernels::vec_dot_arm(fmt, &packed, &xs[c * n..(c + 1) * n], arm);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "{fmt} arm={} t={t} col={c}",
                        arm.name()
                    );
                }
            }
            // Public dispatch-selected entry point agrees per column.
            let mut auto = vec![0f32; t];
            quant::codec(fmt).vec_dot_mat(&packed, &xs, n, &mut auto);
            for (c, &got) in auto.iter().enumerate() {
                let want = quant::vec_dot(fmt, &packed, &xs[c * n..(c + 1) * n]).unwrap();
                assert_eq!(got.to_bits(), want.to_bits(), "{fmt} dispatch t={t} col={c}");
            }
        }
    }
}

#[test]
fn vec_dot_rows_mat_bit_identical_across_thread_counts_and_widths() {
    for fmt in QuantFormat::ALL {
        let rows = 13usize;
        let n = fmt.block_weights().max(64) * 2;
        let mut rng = Pcg::new(0x6E19 ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let packed = quant::quantize(fmt, &data, None).unwrap();
        for t in [1usize, 3, 8, 17] {
            let xs: Vec<f32> = (0..t * n).map(|_| rng.next_normal()).collect();
            let mut base = vec![0f32; rows * t];
            quant::vec_dot_rows_mat_with(fmt, &packed, &xs, n, t, &mut base, 1).unwrap();
            // Row-major [rows][t] result == the column-by-column matvec.
            let mut col = vec![0f32; rows];
            for c in 0..t {
                quant::vec_dot_rows_with(fmt, &packed, &xs[c * n..(c + 1) * n], &mut col, 1)
                    .unwrap();
                for (r, &want) in col.iter().enumerate() {
                    assert_eq!(
                        base[r * t + c].to_bits(),
                        want.to_bits(),
                        "{fmt} t={t} row={r} col={c}"
                    );
                }
            }
            for threads in [2usize, 8] {
                let mut out = vec![0f32; rows * t];
                quant::vec_dot_rows_mat_with(fmt, &packed, &xs, n, t, &mut out, threads).unwrap();
                assert_eq!(bits(&out), bits(&base), "{fmt} t={t} threads={threads}");
            }
        }
    }
}

#[test]
fn vec_dot_mat_total_on_arbitrary_bytes() {
    // GEMM kernels are total on any byte pattern, like the decoders.
    let mut rng = Pcg::new(0x6E1A);
    for fmt in QuantFormat::ALL {
        let n = fmt.block_weights() * 3;
        let nb = fmt.row_bytes(n).unwrap();
        let bytes: Vec<u8> = (0..nb).map(|_| rng.next_u64() as u8).collect();
        let xs = vec![1.0f32; 3 * n];
        let mut out = vec![0f32; 3];
        for arm in available_arms() {
            kernels::vec_dot_mat_arm(fmt, &bytes, &xs, n, &mut out, arm);
        }
    }
}

#[test]
fn decode_and_vec_dot_total_on_arbitrary_bytes() {
    // Decoders are total: any byte pattern decodes (and dots) without
    // panicking through both arms — the loader may see corrupt input.
    let mut rng = Pcg::new(0xB1D);
    for fmt in QuantFormat::ALL {
        let n = fmt.block_weights() * 3;
        let nb = fmt.row_bytes(n).unwrap();
        let bytes: Vec<u8> = (0..nb).map(|_| rng.next_u64() as u8).collect();
        let x = vec![1.0f32; n];
        let mut out = vec![0f32; n];
        for fast in [false, true] {
            kernels::decode_blocks_pinned(fmt, &bytes, &mut out, fast);
            let _ = kernels::vec_dot_pinned(fmt, &bytes, &x, fast);
        }
    }
}
