//! Decode-kernel property suite: the lane-chunked batch decoders and
//! fused `vec_dot` kernels (`quant::kernels`) against their scalar
//! references, across dispatch arms and thread counts.
//!
//! The contract under test (see `quant/mod.rs` module docs):
//!
//! - `decode_blocks` is **bit-identical** between the lane-kernel arm
//!   and the format modules' scalar loops, at every thread count;
//! - `vec_dot(q, x)` equals `kernels::dot_lanes(decode_blocks(q), x)`
//!   bit-for-bit on both arms (fixed 8-lane reduction order, no FMA);
//! - `vec_dot_rows` is bit-identical at thread counts {1, 2, 8} and
//!   equals the per-row `vec_dot` loop.
//!
//! The runtime dispatch itself (`DSQ_SCALAR_DECODE`) is process-global,
//! so cross-arm assertions go through the pinned seams
//! (`decode_blocks_pinned` / `vec_dot_pinned`); CI additionally reruns
//! the whole suite under `DSQ_SCALAR_DECODE=1` so the env-selected path
//! is exercised on both arms too.

use dsq::quant::{self, kernels, QuantFormat};
use dsq::util::rng::Pcg;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn seeded(fmt: QuantFormat, nblocks: usize, salt: u64) -> (Vec<f32>, Vec<u8>) {
    let n = fmt.block_weights() * nblocks;
    let mut rng = Pcg::new(salt ^ ((fmt.block_bytes() as u64) << 8) ^ nblocks as u64);
    let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let packed = quant::quantize(fmt, &data, None).unwrap();
    (data, packed)
}

#[test]
fn decode_arms_bit_identical_across_thread_counts() {
    for fmt in QuantFormat::ALL {
        for nblocks in [1usize, 4, 9] {
            let (data, packed) = seeded(fmt, nblocks, 0xDECD);
            let n = data.len();
            let mut fast = vec![0f32; n];
            let mut scalar = vec![0f32; n];
            kernels::decode_blocks_pinned(fmt, &packed, &mut fast, true);
            kernels::decode_blocks_pinned(fmt, &packed, &mut scalar, false);
            assert_eq!(bits(&fast), bits(&scalar), "{fmt} nblocks={nblocks} arms");
            // The dispatch-selected parallel path must land on the same
            // bits at every thread count.
            for threads in [1usize, 2, 8] {
                let mut out = vec![0f32; n];
                quant::dequantize_into_with(fmt, &packed, &mut out, threads).unwrap();
                assert_eq!(bits(&out), bits(&fast), "{fmt} nblocks={nblocks} threads={threads}");
            }
        }
    }
}

#[test]
fn vec_dot_matches_decode_then_dot_on_both_arms() {
    for fmt in QuantFormat::ALL {
        let (data, packed) = seeded(fmt, 5, 0xD07D);
        let n = data.len();
        let mut rng = Pcg::new(0xAC71 ^ fmt.block_bytes() as u64);
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let mut decoded = vec![0f32; n];
        kernels::decode_blocks_pinned(fmt, &packed, &mut decoded, false);
        let want = kernels::dot_lanes(&decoded, &x);
        for fast in [false, true] {
            let got = kernels::vec_dot_pinned(fmt, &packed, &x, fast);
            assert_eq!(got.to_bits(), want.to_bits(), "{fmt} fast={fast}");
        }
        // Public dispatch-selected entry point agrees too.
        let got = quant::vec_dot(fmt, &packed, &x).unwrap();
        assert_eq!(got.to_bits(), want.to_bits(), "{fmt} dispatch");
    }
}

#[test]
fn vec_dot_rows_bit_identical_across_thread_counts() {
    for fmt in QuantFormat::ALL {
        let rows = 13usize;
        let n = fmt.block_weights().max(64) * 2;
        let mut rng = Pcg::new(0x505 ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let packed = quant::quantize(fmt, &data, None).unwrap();
        let mut base = vec![0f32; rows];
        quant::vec_dot_rows_with(fmt, &packed, &x, &mut base, 1).unwrap();
        // Serial result is exactly the per-row fused dot.
        let rb = fmt.row_bytes(n).unwrap();
        for (r, row) in packed.chunks_exact(rb).enumerate() {
            let want = quant::vec_dot(fmt, row, &x).unwrap();
            assert_eq!(base[r].to_bits(), want.to_bits(), "{fmt} row {r}");
        }
        for threads in [2usize, 8] {
            let mut out = vec![0f32; rows];
            quant::vec_dot_rows_with(fmt, &packed, &x, &mut out, threads).unwrap();
            assert_eq!(bits(&out), bits(&base), "{fmt} threads={threads}");
        }
    }
}

#[test]
fn fused_matvec_equals_dequantize_then_matvec() {
    // The end-to-end identity the native serving backend relies on:
    // fused vec_dot_rows over encoded rows == decode the whole matrix,
    // then the canonical lane dot per row — bit for bit.
    for fmt in [QuantFormat::Q4K, QuantFormat::Q3K, QuantFormat::Q8_0] {
        let rows = 16usize;
        let n = 1024usize;
        let mut rng = Pcg::new(0xFA57 ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let packed = quant::quantize(fmt, &data, None).unwrap();
        let mut fused = vec![0f32; rows];
        quant::vec_dot_rows(fmt, &packed, &x, &mut fused).unwrap();
        let decoded = quant::dequantize(fmt, &packed, rows * n).unwrap();
        let reference: Vec<f32> = decoded
            .chunks_exact(n)
            .map(|row| kernels::dot_lanes(row, &x))
            .collect();
        assert_eq!(bits(&fused), bits(&reference), "{fmt}");
    }
}

#[test]
fn decode_and_vec_dot_total_on_arbitrary_bytes() {
    // Decoders are total: any byte pattern decodes (and dots) without
    // panicking through both arms — the loader may see corrupt input.
    let mut rng = Pcg::new(0xB1D);
    for fmt in QuantFormat::ALL {
        let n = fmt.block_weights() * 3;
        let nb = fmt.row_bytes(n).unwrap();
        let bytes: Vec<u8> = (0..nb).map(|_| rng.next_u64() as u8).collect();
        let x = vec![1.0f32; n];
        let mut out = vec![0f32; n];
        for fast in [false, true] {
            kernels::decode_blocks_pinned(fmt, &bytes, &mut out, fast);
            let _ = kernels::vec_dot_pinned(fmt, &bytes, &x, fast);
        }
    }
}
