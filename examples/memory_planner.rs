//! Memory planner — §4.4's deployment recommendations as a tool (E9).
//!
//! For every device type and every model/scheme combination, prints
//! whether a single 8-device machine can host it, and the best scheme
//! per device.
//!
//! Run: `cargo run --release --example memory_planner [-- ctx]`

use dsq::memory::{self, devices};
use dsq::model::ModelConfig;
use dsq::scheme::builtin;

fn main() -> anyhow::Result<()> {
    let ctx: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(32_768);

    for model in ["deepseek-r1-671b", "distill-qwen-32b"] {
        let cfg = ModelConfig::by_name(model)?;
        println!("\n## {model} @ {ctx} ctx x {} seqs", memory::DEFAULT_N_SEQ);
        print!("{:<12}", "scheme");
        for d in devices::DEVICES {
            print!(" {:>12}", d.name);
        }
        println!(" {:>9} {:>8}", "per-GPU", "bits");
        for scheme in builtin::all() {
            if scheme.name == "f32" {
                continue;
            }
            let est = memory::estimate(&cfg, &scheme, ctx, memory::DEFAULT_N_SEQ);
            print!("{:<12}", scheme.name);
            for d in devices::DEVICES {
                print!(" {:>12}", if devices::fits(&est, d) { "fits" } else { "-" });
            }
            println!(" {:>8.1}G {:>8.2}", est.per_gpu_gib(), est.avg_bits);
        }
    }

    println!("\n## best (highest-precision) scheme per device, R1-671B:");
    let cfg = ModelConfig::by_name("deepseek-r1-671b")?;
    for d in devices::DEVICES {
        let mut best: Option<(String, f64)> = None;
        for s in builtin::all() {
            if s.name == "f32" {
                continue;
            }
            let est = memory::estimate(&cfg, &s, ctx, memory::DEFAULT_N_SEQ);
            let better = best.as_ref().map_or(true, |(_, b)| est.avg_bits > *b);
            if devices::fits(&est, d) && better {
                best = Some((s.name.clone(), est.avg_bits));
            }
        }
        println!(
            "  8x{:<12} -> {}",
            d.name,
            best.map(|(n, b)| format!("{n} ({b:.2} bpw)"))
                .unwrap_or_else(|| "nothing fits".into())
        );
    }
    Ok(())
}
