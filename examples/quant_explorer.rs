//! Quant explorer — the bpw ↔ reconstruction-error trade-off (E10),
//! plus the effect of importance weighting (imatrix) on each format.
//!
//! Run: `cargo run --release --example quant_explorer`

use dsq::quant::{self, error, QuantFormat};
use dsq::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let n = 256 * 64;
    let mut rng = Pcg::new(2024);
    // Realistic weight-like data: gaussian bulk + heavy-tailed outliers
    // (the "super weights" of Yu et al. that motivate DQ3_K_M).
    let data: Vec<f32> = (0..n)
        .map(|i| {
            let base = rng.next_normal() * 0.02;
            if i % 997 == 0 {
                base * 40.0
            } else {
                base
            }
        })
        .collect();
    // Importance: emphasize a random 5% of weights (as an activation
    // calibration pass would).
    let importance: Vec<f32> = (0..n)
        .map(|_| if rng.next_f32() < 0.05 { 100.0 } else { 1.0 })
        .collect();

    println!(
        "{:<8} {:>7} {:>12} {:>12} {:>16} {:>16}",
        "format", "bpw", "rel-rmse", "max|err|", "imp-rmse plain", "imp-rmse imatrix"
    );
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ] {
        let plain = quant::roundtrip(fmt, &data, None)?;
        let weighted = quant::roundtrip(fmt, &data, Some(&importance))?;
        // rmse restricted to the "important" subset.
        let imp_err = |recon: &[f32]| {
            let (mut num, mut den) = (0f64, 0f64);
            for ((a, b), w) in data.iter().zip(recon).zip(&importance) {
                if *w > 1.0 {
                    let d = (*a - *b) as f64;
                    num += d * d;
                    den += (*a as f64) * (*a as f64);
                }
            }
            (num / den.max(1e-30)).sqrt()
        };
        println!(
            "{:<8} {:>7.4} {:>12.6} {:>12.6} {:>16.6} {:>16.6}",
            fmt.name(),
            fmt.bits_per_weight(),
            error::rel_rmse(&data, &plain),
            error::max_abs_err(&data, &plain),
            imp_err(&plain),
            imp_err(&weighted),
        );
    }
    println!(
        "\n(imp-rmse falling from 'plain' to 'imatrix' shows calibration\n steering the rounding toward important weights — §2.2's PTQ objective)"
    );
    Ok(())
}
