//! End-to-end serving driver (DESIGN.md E12) — the validation run
//! recorded in EXPERIMENTS.md.
//!
//! Loads a real (trained + quantized) checkpoint through the AOT
//! artifacts, serves batched generation requests drawn from the
//! benchmark distribution through the L3 coordinator, and reports
//! latency/throughput per scheme.
//!
//! Run after `make artifacts`:
//! `cargo run --release --example serve_bench -- [requests] [ckpt_tag]`

use dsq::container::{quantize_container, Container};
use dsq::coordinator::{sampler::SamplingParams, Coordinator, Request};
use dsq::eval::{suites, tasks};
use dsq::runtime::Engine;
use dsq::scheme::builtin;
use std::path::{Path, PathBuf};

fn ensure_quantized(ckpt_dir: &Path, tag: &str, scheme: &str) -> anyhow::Result<PathBuf> {
    let f32_path = ckpt_dir.join(format!("{tag}.f32.dsq"));
    if scheme == "f32" {
        return Ok(f32_path);
    }
    let qpath = ckpt_dir.join(format!("{tag}.{scheme}.dsq"));
    if !qpath.exists() {
        let src = Container::open(&f32_path)?;
        quantize_container(&src, &builtin::scheme(scheme)?, None)?.write(&qpath)?;
    }
    Ok(qpath)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(64);
    let tag = args.get(1).cloned().unwrap_or_else(|| "r1".to_string());
    let hlo = PathBuf::from("artifacts/hlo");
    let ckpt_dir = PathBuf::from("artifacts/ckpt");
    if !ckpt_dir.join(format!("{tag}.f32.dsq")).exists() {
        eprintln!("checkpoint artifacts/ckpt/{tag}.f32.dsq missing — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("# serve_bench: {n_requests} requests per scheme, checkpoint {tag}\n");
    for scheme in ["f32", "q4_k_m", "dq3_k_m", "q3_k_m"] {
        let ckpt = ensure_quantized(&ckpt_dir, &tag, scheme)?;
        let t_load = std::time::Instant::now();
        let engine = Engine::load(&hlo, &ckpt)?;
        let load_s = t_load.elapsed().as_secs_f64();
        let mut coord = Coordinator::new(engine);
        for i in 0..n_requests as u64 {
            let suite = &suites::SUITES[(i as usize) % suites::SUITES.len()];
            let q = tasks::eval_question(suite, i);
            coord.submit(Request {
                id: i,
                prompt: q.prompt,
                params: SamplingParams::paper(),
                seed: i.wrapping_mul(0x9E37),
            })?;
        }
        let t0 = std::time::Instant::now();
        let responses = coord.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let d = coord.metrics.decode_summary();
        let p = coord.metrics.prefill_summary();
        println!(
            "scheme {:<10} load+compile {:>5.1}s | prefill med {:>6.1} ms | decode med {:>6.1} ms | {:>6.1} tok/s | {:>5.2} req/s | {} reqs in {:.2}s",
            scheme,
            load_s,
            p.median,
            d.median,
            coord.metrics.tokens_per_sec(),
            responses.len() as f64 / wall,
            responses.len(),
            wall,
        );
    }
    Ok(())
}
