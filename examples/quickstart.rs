//! Quickstart: the 60-second tour of the `dsq` public API.
//!
//! 1. Build a tiny f32 checkpoint in memory (normally `train.py` does
//!    this), 2. quantize it with the paper's DQ3_K_M recipe, 3. inspect
//!    sizes/errors, 4. show the §4.4 memory model for the real 671B
//!    model.
//!
//! Run: `cargo run --release --example quickstart`

use dsq::container::{quantize_container, Container, Writer};
use dsq::memory;
use dsq::model::ModelConfig;
use dsq::quant::error::rel_rmse;
use dsq::quant::QuantFormat;
use dsq::scheme::builtin;
use dsq::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // --- 1. a tiny f32 checkpoint ------------------------------------
    let cfg = ModelConfig::tiny_moe();
    let mut w = Writer::new(cfg.clone(), "f32");
    let mut rng = Pcg::new(42);
    for t in cfg.census() {
        let n: usize = t.shape.iter().product();
        let vals: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
        let payload = dsq::quant::quantize(QuantFormat::F32, &vals, None)?;
        w.add_tensor(&t.name, t.class, t.layer, &t.shape, QuantFormat::F32, &payload)?;
    }
    let f32_ckpt = Container::from_bytes(w.to_bytes())?;
    println!(
        "f32 checkpoint: {} tensors, {:.1} MiB",
        f32_ckpt.tensors.len(),
        f32_ckpt.data_bytes() as f64 / (1 << 20) as f64
    );

    // --- 2. quantize with DQ3_K_M ------------------------------------
    let scheme = builtin::scheme("dq3_k_m")?;
    let q = Container::from_bytes(quantize_container(&f32_ckpt, &scheme, None)?.to_bytes())?;
    println!(
        "dq3_k_m checkpoint: {:.1} MiB ({:.2}x smaller, {:.2} bits/weight)",
        q.data_bytes() as f64 / (1 << 20) as f64,
        f32_ckpt.data_bytes() as f64 / q.data_bytes() as f64,
        scheme.avg_bits(&cfg)
    );

    // --- 3. per-tensor reconstruction error --------------------------
    println!("\nffn_down formats + reconstruction error (dynamic rule at work):");
    for t in q.tensors.iter().filter(|t| t.name.contains("ffn_down")).take(7) {
        let ref_vals = f32_ckpt.dequantize(f32_ckpt.tensor(&t.name)?)?;
        let got = q.dequantize(t)?;
        println!(
            "  {:<34} {:<5} rel-rmse {:.4}",
            t.name,
            t.format.name(),
            rel_rmse(&ref_vals, &got)
        );
    }

    // --- 4. would this fit your machine? (671B memory model) ---------
    let big = ModelConfig::by_name("deepseek-r1-671b")?;
    println!("\nDeepSeek-R1 671B under DQ3_K_M @ 32K ctx:");
    let est = memory::estimate_default(&big, &scheme);
    println!(
        "  weights {:.0}G | total {:.0}GB | per-GPU {:.0}GB",
        est.model_gib(),
        est.total_gib(),
        est.per_gpu_gib()
    );
    for d in dsq::memory::devices::DEVICES {
        println!(
            "  8x{:<12}: {}",
            d.name,
            if dsq::memory::devices::fits(&est, d) { "fits" } else { "does NOT fit" }
        );
    }
    Ok(())
}
