//! Sharded-serving benchmark — the Table-2 8-device deployment plan
//! run as real cooperating shard workers, measured on the scaled 671B
//! census proxy (`deepseek-v3-671b-sim`: the production layer plan
//! with 64 routed experts, so `--shards 8` puts 8 experts per shard
//! exactly like the paper's 256/32-per-device deployment).
//!
//! For each shard count the same prefill + decode workload runs
//! through `ForwardPass::set_sharding(n)`. Logits are bit-identical by
//! the `tests/sharded_identity.rs` suite, so the numbers isolate pure
//! partition/exchange overhead: tokens per second for panel prefill
//! and per-token decode, the exchange-barrier count, and the driver's
//! total wait inside barriers. Per-shard resident weight bytes are
//! verified against the analytic [`dsq::memory::shard_weights`]
//! prediction — any drift fails the bench.
//!
//! Pass `--json-sharded PATH` to write the measurements as JSON (CI's
//! `BENCH_sharded.json`).

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::memory::shard_weights;
use dsq::model::ModelConfig;
use dsq::quant::parallel;
use dsq::runtime::forward::ForwardPass;
use dsq::scheme::Scheme;
use dsq::util::json;
use std::time::Instant;

const MAX_CTX: usize = 96;
const PREFILL_LEN: usize = 48;
const DECODE_STEPS: usize = 48;
const PREFILL_REPS: usize = 3;

fn sim_container() -> anyhow::Result<Container> {
    let src = synthetic_f32_container(&ModelConfig::deepseek_v3_671b_sim(), 0x671B)?;
    let scheme = dsq::scheme::builtin::scheme("q4_k_m")?;
    let threads = parallel::max_threads();
    Container::from_bytes(quantize_container_with(&src, &scheme, None, threads)?.to_bytes())
}

struct Run {
    prefill_tok_s: f64,
    decode_tok_s: f64,
    exchanges: u64,
    exchange_wait_ms: f64,
    resident_max_bytes: u64,
    planned_max_bytes: u64,
}

fn run(q: &Container, threads: usize, scheme: &Scheme, shards: usize) -> anyhow::Result<Run> {
    let mut fwd = ForwardPass::new(Container::from_bytes(q.to_bytes())?, threads, MAX_CTX)?;
    fwd.set_sharding(shards)?;
    let mut scratch = fwd.new_scratch();
    let prompt: Vec<i32> = (0..PREFILL_LEN as i32).map(|i| 2 + (i * 17) % 1000).collect();
    let vocab = fwd.vocab();
    let mut logits = vec![0f32; vocab];

    // Validate the planner contract before timing anything.
    let (resident_max_bytes, planned_max_bytes) = match fwd.shards() {
        Some(sh) => {
            let planned = shard_weights(fwd.config(), scheme, shards)?;
            let planned_totals: Vec<u64> =
                planned.iter().map(|s| s.iter().map(|(_, b)| b).sum()).collect();
            if planned_totals != sh.resident_bytes() {
                anyhow::bail!(
                    "planner-vs-engine drift at {shards} shards: planned {planned_totals:?} \
                     vs resident {:?}",
                    sh.resident_bytes()
                );
            }
            let max = |v: &[u64]| v.iter().copied().max().unwrap_or(0);
            (max(sh.resident_bytes()), max(&planned_totals))
        }
        None => (0, 0),
    };

    // Warm-up wave (lazy allocations, dispatch-arm env lookup).
    let mut cache = fwd.new_cache();
    fwd.forward_tokens(&prompt, &mut cache, &mut scratch, Some(&mut logits))?;

    let (x0, w0) = match fwd.shards() {
        Some(sh) => (sh.exchanges(), sh.exchange_wait_ns()),
        None => (0, 0),
    };

    // Panel prefill, fresh cache per repetition.
    let t0 = Instant::now();
    for _ in 0..PREFILL_REPS {
        let mut c = fwd.new_cache();
        fwd.forward_tokens(&prompt, &mut c, &mut scratch, Some(&mut logits))?;
    }
    let prefill_tok_s = (PREFILL_REPS * PREFILL_LEN) as f64 / t0.elapsed().as_secs_f64();

    // Per-token decode continuing off the warm cache.
    let t0 = Instant::now();
    for step in 0..DECODE_STEPS {
        let tok = 2 + ((step * 13) % 1000) as i32;
        fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits))?;
    }
    let decode_tok_s = DECODE_STEPS as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(&logits);

    let (exchanges, exchange_wait_ms) = match fwd.shards() {
        Some(sh) => (sh.exchanges() - x0, (sh.exchange_wait_ns() - w0) as f64 / 1e6),
        None => (0, 0.0),
    };
    Ok(Run {
        prefill_tok_s,
        decode_tok_s,
        exchanges,
        exchange_wait_ms,
        resident_max_bytes,
        planned_max_bytes,
    })
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json-sharded")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let threads = parallel::max_threads();
    let scheme = dsq::scheme::builtin::scheme("q4_k_m")?;
    let q = sim_container()?;
    println!(
        "# sharded native serving on deepseek-v3-671b-sim / q4_k_m ({threads} threads); \
         shards=0 is the local (unsharded) engine\n"
    );
    let mut rows = Vec::new();
    for shards in [0usize, 1, 2, 4, 8] {
        let r = run(&q, threads, &scheme, shards)?;
        println!(
            "bench sharded/shards-{shards} prefill {:>7.1} tok/s | decode {:>6.1} tok/s | \
             {:>5} exchanges ({:>7.1} ms waited) | max shard resident {:.2} MiB",
            r.prefill_tok_s,
            r.decode_tok_s,
            r.exchanges,
            r.exchange_wait_ms,
            r.resident_max_bytes as f64 / (1 << 20) as f64,
        );
        rows.push(json::obj(vec![
            ("shards", json::num(shards as f64)),
            ("prefill_tok_s", json::num(r.prefill_tok_s)),
            ("decode_tok_s", json::num(r.decode_tok_s)),
            ("exchanges", json::num(r.exchanges as f64)),
            ("exchange_wait_ms", json::num(r.exchange_wait_ms)),
            ("resident_max_bytes", json::num(r.resident_max_bytes as f64)),
            ("planned_max_bytes", json::num(r.planned_max_bytes as f64)),
        ]));
    }
    if let Some(path) = json_path {
        let doc = json::obj(vec![
            ("bench", json::str_("sharded")),
            ("model", json::str_("deepseek-v3-671b-sim")),
            ("scheme", json::str_("q4_k_m")),
            ("cores", json::num(threads as f64)),
            ("prefill_len", json::num(PREFILL_LEN as f64)),
            ("decode_steps", json::num(DECODE_STEPS as f64)),
            ("shard_sweep", json::Value::Arr(rows)),
        ]);
        std::fs::write(&path, json::to_string_pretty(&doc))?;
        eprintln!("wrote sharded bench JSON → {path}");
    }
    Ok(())
}
