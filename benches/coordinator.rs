//! Coordinator micro-benchmarks: sampling, batch packing, task
//! generation — the L3 logic that must never dominate a serving step.

use dsq::coordinator::sampler::{sample, SamplingParams};
use dsq::eval::{suites, tasks};
use dsq::util::bench::Bench;
use dsq::util::rng::Pcg;

fn main() {
    println!("# L3 coordinator micro-benches\n");
    // Sampler over a vocab-512 logits row (the per-token cost).
    let mut rng = Pcg::new(3);
    let logits: Vec<f32> = (0..512).map(|_| rng.next_normal()).collect();
    let params = SamplingParams::paper();
    let mut srng = Pcg::new(4);
    Bench::new()
        .throughput_items(1)
        .run("sampler/top-p-512", || sample(&logits, &params, &mut srng));
    let greedy = SamplingParams::greedy();
    Bench::new()
        .throughput_items(1)
        .run("sampler/greedy-512", || sample(&logits, &greedy, &mut srng));

    // Question generation (used by the eval harness and serve driver).
    for suite in ["MATH 500", "AIME 2024", "MMLU", "LiveCodeBench"] {
        let s = suites::by_name(suite).unwrap();
        let mut qid = 0u64;
        Bench::new().throughput_items(1).run(&format!("taskgen/{suite}"), || {
            qid += 1;
            tasks::eval_question(s, qid).prompt.len()
        });
    }

    // Batch packing: 16 prompts into the fixed [16, 16] token buffer.
    let qs: Vec<_> = (0..16)
        .map(|i| tasks::eval_question(suites::by_name("MATH 500").unwrap(), i))
        .collect();
    Bench::new().run("pack/wave-16", || {
        let mut tokens = vec![0i32; 16 * 16];
        let mut lengths = vec![1i32; 16];
        for (i, q) in qs.iter().enumerate() {
            tokens[i * 16..i * 16 + q.prompt.len()].copy_from_slice(&q.prompt);
            lengths[i] = q.prompt.len() as i32;
        }
        (tokens, lengths)
    });
}
