//! Table 1 / Table 6 regeneration bench: the analytic census + memory
//! model over the full 671B architecture (exercises the scheme engine's
//! per-tensor assignment over 1000+ tensors per scheme).

use dsq::memory;
use dsq::model::ModelConfig;
use dsq::scheme::builtin;
use dsq::util::bench::Bench;

fn main() {
    println!("# table 1 regeneration (671B census × 5 schemes)\n");
    let cfg = ModelConfig::by_name("deepseek-r1-671b").unwrap();
    Bench::new().run("census/deepseek-671b", || cfg.census().len());
    for name in dsq::tables::TABLE1_SCHEMES {
        let scheme = builtin::scheme(name).unwrap();
        Bench::new().run(&format!("estimate/{name}"), || {
            memory::estimate_default(&cfg, &scheme).total_bytes
        });
    }
    Bench::quick().run("table1/full-render", || dsq::tables::table1(true).unwrap().len());
    Bench::quick().run("table7/full-render", || dsq::tables::table7().unwrap().len());

    // And print the tables themselves — the bench IS the regenerator.
    println!("\n{}", dsq::tables::table1(true).unwrap());
    println!("{}", dsq::tables::table7().unwrap());
    println!("{}", dsq::tables::table8(false));
}
