//! End-to-end serving benchmark over the PJRT runtime (needs
//! `make artifacts`; exits gracefully when artifacts are absent).
//!
//! Measures prefill latency, decode-step latency and wave throughput
//! per quantization scheme — the data for EXPERIMENTS.md §Perf.

use dsq::container::{quantize_container, Container};
use dsq::coordinator::{sampler::SamplingParams, Coordinator, Request};
use dsq::eval::{suites, tasks};
use dsq::runtime::Engine;
use dsq::scheme::builtin;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let hlo = PathBuf::from("artifacts/hlo");
    let ckpt_dir = PathBuf::from("artifacts/ckpt");
    // Prefer a trained checkpoint; fall back to the smoke one.
    let tag = ["r1", "v3", "smoke"]
        .into_iter()
        .find(|t| ckpt_dir.join(format!("{t}.f32.dsq")).exists());
    let Some(tag) = tag else {
        eprintln!("serving bench skipped: no checkpoints (run `make artifacts`)");
        return Ok(());
    };
    println!("# serving bench on checkpoint {tag}\n");
    for scheme in ["f32", "q4_k_m", "dq3_k_m", "q2_k_l"] {
        let f32_path = ckpt_dir.join(format!("{tag}.f32.dsq"));
        let path = if scheme == "f32" {
            f32_path
        } else {
            let q = ckpt_dir.join(format!("{tag}.{scheme}.dsq"));
            if !q.exists() {
                let src = Container::open(&f32_path)?;
                quantize_container(&src, &builtin::scheme(scheme)?, None)?.write(&q)?;
            }
            q
        };
        let t0 = std::time::Instant::now();
        let engine = Engine::load(&hlo, &path)?;
        let compile_s = t0.elapsed().as_secs_f64();
        let mut coord = Coordinator::new(engine);
        for i in 0..64u64 {
            let suite = &suites::SUITES[(i % 9) as usize];
            let q = tasks::eval_question(suite, i);
            coord.submit(Request {
                id: i,
                prompt: q.prompt,
                params: SamplingParams::paper(),
                seed: i,
            })?;
        }
        let t0 = std::time::Instant::now();
        coord.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let p = coord.metrics.prefill_summary();
        let d = coord.metrics.decode_summary();
        println!(
            "bench serving/{:<10} compile {:>5.1}s | prefill med {:>7.1} ms | decode med {:>7.1} ms | {:>7.1} tok/s | 64 reqs in {:.2}s",
            scheme,
            compile_s,
            p.median,
            d.median,
            coord.metrics.tokens_per_sec(),
            wall
        );
    }
    Ok(())
}
