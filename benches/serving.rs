//! End-to-end serving benchmark over the PJRT runtime (needs
//! `make artifacts`; exits gracefully when artifacts are absent).
//!
//! Measures prefill latency, decode-step latency and wave throughput
//! per quantization scheme — the data for EXPERIMENTS.md §Perf.

use dsq::container::{quantize_container, Container};
use dsq::coordinator::{sampler::SamplingParams, Coordinator, Request};
use dsq::eval::{suites, tasks};
use dsq::quant::parallel;
use dsq::runtime::{loader, Engine};
use dsq::scheme::builtin;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let hlo = PathBuf::from("artifacts/hlo");
    let ckpt_dir = PathBuf::from("artifacts/ckpt");
    // Prefer a trained checkpoint; fall back to the smoke one.
    let tag = ["r1", "v3", "smoke"]
        .into_iter()
        .find(|t| ckpt_dir.join(format!("{t}.f32.dsq")).exists());
    let Some(tag) = tag else {
        eprintln!("serving bench skipped: no checkpoints (run `make artifacts`)");
        return Ok(());
    };
    println!("# serving bench on checkpoint {tag}\n");

    // Weight-loader decode bench (artifact-free): prepare f32 literal
    // payloads from a quantized container, serial vs fanned-out.
    {
        let f32_path = ckpt_dir.join(format!("{tag}.f32.dsq"));
        let src = Container::open(&f32_path)?;
        let q = Container::from_bytes(
            quantize_container(&src, &builtin::scheme("dq3_k_m")?, None)?.to_bytes(),
        )?;
        let manifest = loader::f32_weight_manifest(&q);
        let cores = parallel::max_threads();
        let time = |threads: usize| -> anyhow::Result<f64> {
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                std::hint::black_box(loader::prepare_weights(&manifest, &q, threads)?);
                best = best.min(t0.elapsed().as_secs_f64());
            }
            Ok(best)
        };
        let serial = time(1)?;
        let par = time(cores)?;
        println!(
            "bench loader-decode/dq3_k_m serial {serial:>8.4} s | parallel-{cores} {par:>8.4} s | {:.2}x\n",
            serial / par
        );
    }
    for scheme in ["f32", "q4_k_m", "dq3_k_m", "q2_k_l"] {
        let f32_path = ckpt_dir.join(format!("{tag}.f32.dsq"));
        let path = if scheme == "f32" {
            f32_path
        } else {
            let q = ckpt_dir.join(format!("{tag}.{scheme}.dsq"));
            if !q.exists() {
                let src = Container::open(&f32_path)?;
                quantize_container(&src, &builtin::scheme(scheme)?, None)?.write(&q)?;
            }
            q
        };
        let t0 = std::time::Instant::now();
        let engine = Engine::load(&hlo, &path)?;
        let compile_s = t0.elapsed().as_secs_f64();
        let mut coord = Coordinator::new(engine);
        for i in 0..64u64 {
            let suite = &suites::SUITES[(i % 9) as usize];
            let q = tasks::eval_question(suite, i);
            coord.submit(Request {
                id: i,
                prompt: q.prompt,
                params: SamplingParams::paper(),
                seed: i,
            })?;
        }
        let t0 = std::time::Instant::now();
        coord.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let p = coord.metrics.prefill_summary();
        let d = coord.metrics.decode_summary();
        println!(
            "bench serving/{:<10} compile {:>5.1}s | prefill med {:>7.1} ms | decode med {:>7.1} ms | {:>7.1} tok/s | 64 reqs in {:.2}s",
            scheme,
            compile_s,
            p.median,
            d.median,
            coord.metrics.tokens_per_sec(),
            wall
        );
    }
    Ok(())
}
