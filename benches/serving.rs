//! Serving benchmark for the continuous-batching scheduler — artifact
//! free: a synthetic tiny-moe container quantized to Q4_K_M, no HLO.
//!
//! Two sections:
//!
//! 1. **Batched-panel decode vs per-slot decode.** The same decode
//!    workload (prefilled slots advanced 64 steps) run once as one
//!    `forward_step_batch` GEMM panel per step and once as a
//!    `forward_token` loop over the slots, at batch 1/4/8/16. The
//!    panel amortizes each weight tile's dequantization across the
//!    batch, so it must win from batch ≥ 4 (the PR 7 acceptance bar).
//! 2. **Poisson open-loop load sweep.** Requests arrive with
//!    exponential inter-arrival times at 0.5×/1.0×/2.0× the calibrated
//!    closed-loop service rate and are pushed through a
//!    `ContinuousScheduler`; per-request latency (arrival →
//!    completion, queue wait included) and goodput are reported per
//!    offered load.
//! 3. **Context-length × KV-scheme sweep.** Slots are decoded out to
//!    increasing context bounds under `f32` and `q8_0` KV caches,
//!    reporting decode throughput and the resident KV bytes at full
//!    context — the serving-side measurement behind ROADMAP item 5
//!    (KV, not weights, is the marginal byte at long context; q8_0
//!    holds ~3.8× more tokens in the same budget).
//!
//! Pass `--json-serving PATH` to write the measurements as JSON (CI's
//! `BENCH_serving.json`; the sweep lands under `kv_ctx_sweep`).

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::scheduler::{ContinuousScheduler, ServeConfig, SubmitOutcome};
use dsq::coordinator::{sampler::SamplingParams, Request};
use dsq::eval::{suites, tasks};
use dsq::model::ModelConfig;
use dsq::quant::{parallel, KvScheme};
use dsq::runtime::native::NativeEngine;
use dsq::scheme::builtin;
use dsq::util::json;
use dsq::util::rng::Pcg;
use std::time::{Duration, Instant};

fn q4_container() -> anyhow::Result<Container> {
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xBE7C)?;
    let scheme = builtin::scheme("q4_k_m")?;
    Container::from_bytes(quantize_container_with(&src, &scheme, None, 1)?.to_bytes())
}

fn make_req(id: u64) -> Request {
    let suite = &suites::SUITES[(id % suites::SUITES.len() as u64) as usize];
    let q = tasks::eval_question(suite, id);
    Request { id, prompt: q.prompt, params: SamplingParams::paper(), seed: id ^ 0x5eed }
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

/// Decode `steps` tokens across `k` prefilled slots; `panel` selects
/// one `forward_step_batch` per step vs a `forward_token` loop.
/// Returns live slot-steps per second.
fn decode_rate(engine: &NativeEngine, k: usize, steps: usize, panel: bool) -> anyhow::Result<f64> {
    let fwd = engine.forward();
    let v = engine.vocab();
    let prompt: Vec<i32> = (0..16).map(|i| 3 + (i * 11) % 400).collect();
    let mut caches: Vec<_> = (0..k).map(|_| fwd.new_cache()).collect();
    let mut scratch = fwd.new_scratch_cols(k);
    for cache in caches.iter_mut() {
        fwd.forward_tokens(&prompt, cache, &mut scratch, None)?;
    }
    let live = vec![true; k];
    let mut logits = vec![0f32; k * v];
    let t0 = Instant::now();
    for step in 0..steps {
        let toks: Vec<i32> = (0..k).map(|s| ((step * 7 + s * 13) % 400) as i32 + 2).collect();
        if panel {
            fwd.forward_step_batch(&toks, &live, &mut caches, &mut scratch, &mut logits)?;
        } else {
            for (s, cache) in caches.iter_mut().enumerate() {
                let row = &mut logits[s * v..(s + 1) * v];
                fwd.forward_token(toks[s], cache, &mut scratch, Some(row))?;
            }
        }
    }
    std::hint::black_box(&logits);
    Ok((k * steps) as f64 / t0.elapsed().as_secs_f64())
}

/// Prefill `k` slots (16 tokens) and decode them out to `ctx` as GEMM
/// panels. Returns (decode slot-steps/s, resident KV bytes across the
/// slots at full context).
fn ctx_fill(engine: &NativeEngine, k: usize, ctx: usize) -> anyhow::Result<(f64, u64)> {
    let fwd = engine.forward();
    let v = engine.vocab();
    let prompt: Vec<i32> = (0..16).map(|i| 3 + (i * 11) % 400).collect();
    let mut caches: Vec<_> = (0..k).map(|_| fwd.new_cache()).collect();
    let mut scratch = fwd.new_scratch_cols(k);
    for cache in caches.iter_mut() {
        fwd.forward_tokens(&prompt, cache, &mut scratch, None)?;
    }
    let live = vec![true; k];
    let mut logits = vec![0f32; k * v];
    let steps = ctx - prompt.len();
    let t0 = Instant::now();
    for step in 0..steps {
        let toks: Vec<i32> = (0..k).map(|s| ((step * 7 + s * 13) % 400) as i32 + 2).collect();
        fwd.forward_step_batch(&toks, &live, &mut caches, &mut scratch, &mut logits)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&logits);
    let resident: u64 = caches.iter().map(|c| c.resident_bytes() as u64).sum();
    Ok(((k * steps) as f64 / dt, resident))
}

/// One open-loop run: `n_req` Poisson arrivals at `lambda` req/s.
/// Returns (p50_ms, p99_ms, goodput_tok_s, wall_s).
fn open_loop(
    engine: &NativeEngine,
    lambda: f64,
    n_req: usize,
    seed: u64,
) -> anyhow::Result<(f64, f64, f64, f64)> {
    let mut rng = Pcg::new(seed);
    let mut arrivals = Vec::with_capacity(n_req);
    let mut t = 0.0f64;
    for _ in 0..n_req {
        // Exponential inter-arrival; 1-u keeps ln() away from 0.
        t += -(1.0 - rng.next_f64()).ln() / lambda;
        arrivals.push(t);
    }
    let mut sched = ContinuousScheduler::new(engine, ServeConfig::default())?;
    let mut latencies = Vec::with_capacity(n_req);
    let mut tokens = 0u64;
    let t0 = Instant::now();
    let mut next = 0usize;
    loop {
        let now = t0.elapsed().as_secs_f64();
        while next < n_req && arrivals[next] <= now {
            match sched.submit(make_req(next as u64))? {
                SubmitOutcome::Queued => {}
                SubmitOutcome::Backpressure(_) => unreachable!("unbounded queue"),
            }
            next += 1;
        }
        let worked = sched.step()?;
        for r in sched.take_responses() {
            let done = t0.elapsed().as_secs_f64();
            latencies.push((done - arrivals[r.id as usize]) * 1e3);
            tokens += r.n_generated as u64;
        }
        if next >= n_req && sched.pending() == 0 && sched.live() == 0 {
            break;
        }
        if !worked && next < n_req {
            let wait = arrivals[next] - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait.min(1e-4)));
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok((pct(&latencies, 0.5), pct(&latencies, 0.99), tokens as f64 / wall, wall))
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json-serving")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let threads = parallel::max_threads();
    let q = q4_container()?;

    // --- 1. batched-panel decode vs a per-slot token loop ---
    // A taller context than the serving default so every slot can take
    // 16 prompt + 64 decode tokens.
    let engine = NativeEngine::with_limits(Container::from_bytes(q.to_bytes())?, threads, 16, 16, 96)?;
    println!("# decode: one GEMM panel per step vs per-slot token loop ({threads} threads)\n");
    let mut panel_report = Vec::new();
    for k in [1usize, 4, 8, 16] {
        let steps = 64;
        let per_slot = decode_rate(&engine, k, steps, false)?;
        let panel = decode_rate(&engine, k, steps, true)?;
        let speedup = panel / per_slot;
        println!(
            "bench serving/decode-batch-{k:<2} per-slot {per_slot:>8.1} slot-steps/s | \
             panel {panel:>8.1} slot-steps/s | {speedup:.2}x"
        );
        panel_report.push(json::obj(vec![
            ("batch", json::num(k as f64)),
            ("per_slot_steps_per_s", json::num(per_slot)),
            ("panel_steps_per_s", json::num(panel)),
            ("speedup", json::num(speedup)),
        ]));
    }

    // --- 2. Poisson-arrival open-loop sweep ---
    // Calibrate the closed-loop service rate, then offer 0.5×/1×/2×.
    let engine = NativeEngine::from_container(Container::from_bytes(q.to_bytes())?, threads)?;
    let calib_n = 48usize;
    let t0 = Instant::now();
    {
        let mut sched = ContinuousScheduler::new(&engine, ServeConfig::default())?;
        for id in 0..calib_n as u64 {
            match sched.submit(make_req(id))? {
                SubmitOutcome::Queued => {}
                SubmitOutcome::Backpressure(_) => unreachable!("unbounded queue"),
            }
        }
        sched.run_to_completion()?;
    }
    let mu = calib_n as f64 / t0.elapsed().as_secs_f64();
    println!("\n# open-loop Poisson sweep: closed-loop service rate ≈ {mu:.1} req/s\n");
    let mut load_report = Vec::new();
    for (i, factor) in [0.5f64, 1.0, 2.0].iter().enumerate() {
        let lambda = factor * mu;
        let (p50, p99, goodput, wall) = open_loop(&engine, lambda, 96, 0xA0 + i as u64)?;
        println!(
            "bench serving/open-loop-{factor:.1}x offered {lambda:>8.1} req/s | \
             p50 {p50:>7.2} ms | p99 {p99:>7.2} ms | goodput {goodput:>8.1} tok/s \
             ({wall:.2}s wall)"
        );
        load_report.push(json::obj(vec![
            ("load_factor", json::num(*factor)),
            ("offered_rps", json::num(lambda)),
            ("p50_ms", json::num(p50)),
            ("p99_ms", json::num(p99)),
            ("goodput_tok_s", json::num(goodput)),
            ("wall_s", json::num(wall)),
        ]));
    }

    // --- 3. context-length × KV-scheme sweep ---
    // Same decode workload pushed to increasing context bounds under
    // f32 and q8_0 KV; resident bytes are measured on the live caches,
    // not estimated, so a planner/engine drift would show up here too.
    println!("\n# context sweep: decode rate + resident KV bytes, f32 vs q8_0 KV\n");
    let sweep_k = 4usize;
    let mut ctx_report = Vec::new();
    for kv in [KvScheme::F32, KvScheme::Q8_0] {
        for ctx in [32usize, 64, 96] {
            let mut engine = NativeEngine::with_limits(
                Container::from_bytes(q.to_bytes())?,
                threads,
                sweep_k,
                16,
                ctx,
            )?;
            engine.set_kv_scheme(kv)?;
            let bpt = engine.kv_bytes_per_token();
            let (rate, resident) = ctx_fill(&engine, sweep_k, ctx)?;
            println!(
                "bench serving/kv-ctx-{}-{ctx:<3} {rate:>8.1} slot-steps/s | \
                 {resident:>8} B resident KV ({bpt} B/token x {sweep_k} slots)",
                kv.name()
            );
            ctx_report.push(json::obj(vec![
                ("kv_scheme", json::str_(kv.name())),
                ("ctx", json::num(ctx as f64)),
                ("batch", json::num(sweep_k as f64)),
                ("panel_steps_per_s", json::num(rate)),
                ("resident_kv_bytes", json::num(resident as f64)),
                ("kv_bytes_per_token", json::num(bpt as f64)),
            ]));
        }
    }

    if let Some(path) = json_path {
        let doc = json::obj(vec![
            ("bench", json::str_("serving")),
            ("model", json::str_("tiny-moe")),
            ("scheme", json::str_("q4_k_m")),
            ("cores", json::num(threads as f64)),
            // Shard count of the serving engine (0 = local/unsharded;
            // the shard-count sweep lives in `benches/sharded.rs`).
            ("shards", json::num(engine.shard_count() as f64)),
            ("decode_panel", json::Value::Arr(panel_report)),
            ("offered_load", json::Value::Arr(load_report)),
            ("kv_ctx_sweep", json::Value::Arr(ctx_report)),
        ]);
        std::fs::write(&path, json::to_string_pretty(&doc))?;
        eprintln!("wrote serving bench JSON → {path}");
    }
    Ok(())
}
