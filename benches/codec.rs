//! Codec micro-benchmarks: quantize + dequantize throughput per format,
//! with and without importance weighting. This is the L3-side hot path
//! of `dsq quantize` (the serving hot path dequantizes inside XLA).

use dsq::quant::{self, QuantFormat};
use dsq::util::bench::Bench;
use dsq::util::rng::Pcg;

fn main() {
    let n = 256 * 256; // 64K weights ≈ one tiny-moe expert matrix
    let mut rng = Pcg::new(1);
    let data: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
    let importance: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();

    println!("# codec throughput, {n} weights/iter\n");
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ] {
        let bytes = (n * 4) as u64;
        Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("quantize/{}", fmt.name()), || {
                quant::quantize(fmt, &data, None).unwrap()
            });
        Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("quantize-imatrix/{}", fmt.name()), || {
                quant::quantize(fmt, &data, Some(&importance)).unwrap()
            });
        let packed = quant::quantize(fmt, &data, None).unwrap();
        Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("dequantize/{}", fmt.name()), || {
                quant::dequantize(fmt, &packed, n).unwrap()
            });
    }
}
