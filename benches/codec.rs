//! Codec micro-benchmarks: quantize + dequantize throughput per format
//! through the zero-copy `BlockCodec` entry points, serial vs
//! block-parallel, with and without importance weighting — plus the
//! scale-search benchmark (PR-1 two-pass baseline vs the current
//! single-pass lane-chunked search for the Q3_K/Q4_K hot paths), the
//! **decode-path benchmarks** (PR-2 scalar `decode_blocks` baseline vs
//! the PR-3 lane kernels, per format and over a whole DQ3_K_M
//! container, so the encode/decode asymmetry is visible in one run),
//! the **fused `vec_dot_rows` vs dequantize-then-dot** comparison on a
//! 7168-wide row batch (the serving matvec shape), and the headline
//! container benchmark: multi-tensor Q4_K container quantization,
//! serial vs tensor-parallel (the `dsq quantize` hot path).
//!
//! Since PR 9 the decode section also measures **GGUF import
//! throughput**: the `dsq import` transcode of a llama.cpp-layout
//! q4_k_m checkpoint into the DSQ1 container, serial vs
//! tensor-parallel (`gguf_import_parallel_speedup` in the summary).
//!
//! Pass `--json PATH` to additionally write every measurement (and the
//! speedup summary) as a JSON report — CI uploads it as an artifact.
//! Pass `--json-decode PATH` to also write the decode-side measurements
//! alone (CI's `BENCH_decode.json`, seeding the decode perf trajectory),
//! and `--json-forward PATH` for the **native forward-pass tokens/s**
//! section alone (CI's `BENCH_forward.json`): prefill + greedy decode
//! through the full step on encoded DQ3_K_M / Q4_K_M weights — the
//! MLA+MoE tiny-moe series plus, since PR 5, a tiny-dense (GQA,
//! Table 5) series — serial vs row-parallel matvecs, with per-phase
//! heap-allocation counts (prefill pays the lazy KV buffer; decode
//! must report 0 allocations per token). Since PR 6 the forward
//! section also measures **panel prefill**: a 64-token prompt through
//! the quantized-GEMM `forward_tokens` pass vs the per-token loop,
//! with the speedup ratio in the summary (`prefill_*_panel_speedup`).

use dsq::container::{gguf, quantize_container_with, synthetic_f32_container, Container};
use dsq::model::ModelConfig;
use dsq::quant::{self, kernels, parallel, scalar, QuantFormat};
use dsq::runtime::forward::{ForwardPass, MatvecMode};
use dsq::runtime::native::NATIVE_MAX_CTX;
use dsq::scheme::builtin;
use dsq::util::bench::{Bench, BenchResult};
use dsq::util::json;
use dsq::util::rng::Pcg;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

// --- allocation counter for the forward-pass discipline report ---
// The decode loop must be allocation-free (per-slot scratch reuse +
// lazy KV buffers); the bench counts allocation events around prefill
// and decode and reports both in BENCH_forward.json.

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// --- PR-1 scale-search baseline (two passes per candidate, closure
// weight lookup) — kept verbatim here so the speedup of the current
// single-pass lane-chunked search stays measurable against it. ---

fn nearest_int(x: f32) -> i32 {
    x.round() as i32
}

fn baseline_make_qx_quants(x: &[f32], nmax: i32, weights: Option<&[f32]>, out: &mut [u8]) -> f32 {
    let n = x.len();
    let mut amax = 0f32;
    let mut max = 0f32;
    for &v in x {
        if v.abs() > amax {
            amax = v.abs();
            max = v;
        }
    }
    if amax < 1e-30 {
        out.iter_mut().for_each(|o| *o = nmax as u8);
        return 0.0;
    }
    let mut best_scale = 0f32;
    let mut best_err = f32::INFINITY;
    let w_at = |i: usize| weights.map_or(x[i] * x[i] + 1e-8, |w| w[i] + 1e-10);
    for is in -9i32..=9 {
        let iscale = -(nmax as f32 + 0.1f32 * is as f32) / max;
        let mut sumlx = 0f32;
        let mut suml2 = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * x[i]).clamp(-nmax, nmax - 1) as f32;
            let w = w_at(i);
            sumlx += w * x[i] * l;
            suml2 += w * l * l;
        }
        if suml2 <= 0.0 {
            continue;
        }
        let scale = sumlx / suml2;
        let mut err = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * x[i]).clamp(-nmax, nmax - 1) as f32;
            let d = x[i] - scale * l;
            err += w_at(i) * d * d;
        }
        if err < best_err {
            best_err = err;
            best_scale = scale;
        }
    }
    if best_scale == 0.0 {
        best_scale = max / -(nmax as f32);
    }
    let inv = if best_scale != 0.0 { 1.0 / best_scale } else { 0.0 };
    for i in 0..n {
        let l = nearest_int(inv * x[i]).clamp(-nmax, nmax - 1);
        out[i] = (l + nmax) as u8;
    }
    best_scale
}

fn baseline_make_qkx_quants(
    x: &[f32],
    nmax: i32,
    weights: Option<&[f32]>,
    out: &mut [u8],
) -> (f32, f32) {
    let n = x.len();
    let mut vmin = x[0];
    let mut vmax = x[0];
    for &v in x {
        vmin = vmin.min(v);
        vmax = vmax.max(v);
    }
    if vmax <= vmin + 1e-30 {
        if vmin >= 0.0 {
            out.iter_mut().for_each(|o| *o = nmax as u8);
            return (vmin / nmax as f32, 0.0);
        }
        out.iter_mut().for_each(|o| *o = 0);
        return (0.0, -vmin);
    }
    if vmin > 0.0 {
        vmin = 0.0;
    }
    let w_at = |i: usize| weights.map_or(x[i] * x[i] + 1e-8, |w| w[i] + 1e-10);
    let mut best = (vmax - vmin) / nmax as f32;
    let mut best_min = -vmin;
    let mut best_err = f32::INFINITY;
    for step in -5i32..=8 {
        let iscale = (0.1f32 * step as f32 + nmax as f32) / (vmax - vmin);
        let mut sum_w = 0f32;
        let mut sum_x = 0f32;
        let mut sum_l = 0f32;
        let mut sum_l2 = 0f32;
        let mut sum_xl = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * (x[i] - vmin)).clamp(0, nmax) as f32;
            let w = w_at(i);
            sum_w += w;
            sum_x += w * x[i];
            sum_l += w * l;
            sum_l2 += w * l * l;
            sum_xl += w * x[i] * l;
        }
        let det = sum_w * sum_l2 - sum_l * sum_l;
        if det <= 0.0 {
            continue;
        }
        let mut scale = (sum_w * sum_xl - sum_x * sum_l) / det;
        let mut minv = (sum_l2 * sum_x - sum_l * sum_xl) / det;
        if minv > 0.0 {
            minv = 0.0;
            scale = if sum_l2 > 0.0 { sum_xl / sum_l2 } else { scale };
        }
        if scale <= 0.0 {
            continue;
        }
        let mut err = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * (x[i] - vmin)).clamp(0, nmax) as f32;
            let d = x[i] - (scale * l + minv);
            err += w_at(i) * d * d;
        }
        if err < best_err {
            best_err = err;
            best = scale;
            best_min = -minv;
        }
    }
    let inv = if best > 0.0 { 1.0 / best } else { 0.0 };
    for i in 0..n {
        out[i] = nearest_int(inv * (x[i] + best_min)).clamp(0, nmax) as u8;
    }
    (best, best_min)
}

fn result_json(r: &BenchResult) -> json::Value {
    json::obj(vec![
        ("name", json::str_(&r.name)),
        ("median_ns", json::num(r.median_ns)),
        ("p10_ns", json::num(r.p10_ns)),
        ("p90_ns", json::num(r.p90_ns)),
        ("iters_per_batch", json::num(r.iters_per_batch as f64)),
        ("batches", json::num(r.batches as f64)),
    ])
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let json_decode_path = argv
        .iter()
        .position(|a| a == "--json-decode")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let json_forward_path = argv
        .iter()
        .position(|a| a == "--json-forward")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let mut report: Vec<json::Value> = Vec::new();
    let mut summary: Vec<(String, f64)> = Vec::new();
    let mut decode_report: Vec<json::Value> = Vec::new();
    let mut decode_summary: Vec<(String, f64)> = Vec::new();
    let mut forward_report: Vec<json::Value> = Vec::new();
    let mut forward_summary: Vec<(String, f64)> = Vec::new();

    let n = 256 * 1024; // 256K weights ≈ a large expert matrix slice
    let mut rng = Pcg::new(1);
    let data: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
    let importance: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
    let cores = parallel::max_threads();

    println!("# codec throughput, {n} weights/iter, {cores} cores\n");
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ] {
        let bytes = (n * 4) as u64;
        let mut packed = vec![0u8; fmt.row_bytes(n)?];
        report.push(result_json(
            &Bench::new()
                .throughput_bytes(bytes)
                .run(&format!("quantize-serial/{}", fmt.name()), || {
                    quant::quantize_into_with(fmt, &data, None, &mut packed, 1).unwrap()
                }),
        ));
        report.push(result_json(
            &Bench::new()
                .throughput_bytes(bytes)
                .run(&format!("quantize-par{cores}/{}", fmt.name()), || {
                    quant::quantize_into_with(fmt, &data, None, &mut packed, cores).unwrap()
                }),
        ));
        // Pinned to 1 thread so the imatrix overhead reads directly
        // against the quantize-serial row above.
        report.push(result_json(
            &Bench::new()
                .throughput_bytes(bytes)
                .run(&format!("quantize-imatrix-serial/{}", fmt.name()), || {
                    quant::quantize_into_with(fmt, &data, Some(&importance), &mut packed, 1)
                        .unwrap()
                }),
        ));
        quant::quantize_into(fmt, &data, None, &mut packed)?;
        let mut decoded = vec![0f32; n];
        report.push(result_json(
            &Bench::new()
                .throughput_bytes(bytes)
                .run(&format!("dequantize/{}", fmt.name()), || {
                    quant::dequantize_into(fmt, &packed, &mut decoded).unwrap()
                }),
        ));
    }

    // --- scale search: PR-1 baseline vs current, on the Q3_K (16-weight
    // symmetric) and Q4_K (32-weight asymmetric) sub-block shapes. The
    // acceptance bar is ≥1.5× on each.
    println!("\n# scale search, {n} weights/iter as sub-block sweeps\n");
    let mut codes = vec![0u8; n];
    let qx_base = Bench::new().throughput_items(n as u64).run("scale-search-qx16-baseline", || {
        let mut acc = 0f32;
        for (xs, os) in data.chunks_exact(16).zip(codes.chunks_exact_mut(16)) {
            acc += baseline_make_qx_quants(xs, 4, None, os);
        }
        acc
    });
    let qx_new = Bench::new().throughput_items(n as u64).run("scale-search-qx16-current", || {
        let mut acc = 0f32;
        for (xs, os) in data.chunks_exact(16).zip(codes.chunks_exact_mut(16)) {
            acc += scalar::make_qx_quants(xs, 4, None, os);
        }
        acc
    });
    let qkx_base = Bench::new().throughput_items(n as u64).run("scale-search-qkx32-baseline", || {
        let mut acc = 0f32;
        for (xs, os) in data.chunks_exact(32).zip(codes.chunks_exact_mut(32)) {
            acc += baseline_make_qkx_quants(xs, 15, None, os).0;
        }
        acc
    });
    let qkx_new = Bench::new().throughput_items(n as u64).run("scale-search-qkx32-current", || {
        let mut acc = 0f32;
        for (xs, os) in data.chunks_exact(32).zip(codes.chunks_exact_mut(32)) {
            acc += scalar::make_qkx_quants(xs, 15, None, os).0;
        }
        acc
    });
    let qx_speedup = qx_base.median_ns / qx_new.median_ns;
    let qkx_speedup = qkx_base.median_ns / qkx_new.median_ns;
    println!(
        "speedup scale-search qx16 (Q3_K/Q6_K path): {qx_speedup:.2}x vs PR-1 baseline\n\
         speedup scale-search qkx32 (Q4_K/Q5_K path): {qkx_speedup:.2}x vs PR-1 baseline"
    );
    for r in [&qx_base, &qx_new, &qkx_base, &qkx_new] {
        report.push(result_json(r));
    }
    summary.push(("qx16_speedup".to_string(), qx_speedup));
    summary.push(("qkx32_speedup".to_string(), qkx_speedup));

    // --- decode kernels (PR 3): the PR-2 scalar `decode_blocks` loops
    // vs the lane-chunked batch kernels, pinned per arm so the numbers
    // measure the kernels and not the dispatch. Throughput is GB/s of
    // decoded f32, the unit the serving loader sees. The acceptance bar
    // is ≥2× on Q4_K (and on the DQ3_K_M container below).
    println!("\n# decode kernels: scalar reference vs lane kernels, {n} weights/iter\n");
    let gibps = |bytes: u64, r: &BenchResult| bytes as f64 / r.median_ns * 1e9 / (1u64 << 30) as f64;
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ] {
        let mut packed = vec![0u8; fmt.row_bytes(n)?];
        quant::quantize_into_with(fmt, &data, None, &mut packed, cores)?;
        let mut decoded = vec![0f32; n];
        let bytes = (n * 4) as u64;
        let scalar_arm = Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("decode-scalar/{}", fmt.name()), || {
                kernels::decode_blocks_pinned(fmt, &packed, &mut decoded, false)
            });
        let lane_arm = Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("decode-lanes/{}", fmt.name()), || {
                kernels::decode_blocks_pinned(fmt, &packed, &mut decoded, true)
            });
        let speedup = scalar_arm.median_ns / lane_arm.median_ns;
        println!(
            "decode {:<5} scalar {:>6.2} GiB/s → lanes {:>6.2} GiB/s  ({speedup:.2}x)",
            fmt.name(),
            gibps(bytes, &scalar_arm),
            gibps(bytes, &lane_arm),
        );
        decode_report.push(result_json(&scalar_arm));
        decode_report.push(result_json(&lane_arm));
        decode_summary.push((format!("decode_{}_speedup", fmt.name()), speedup));
    }

    // --- fused vec_dot_rows vs dequantize-then-dot on the serving
    // matvec shape: 7168-wide rows (the 671B hidden size). The fused
    // path must win — it reads packed bytes once and never materializes
    // the f32 matrix.
    let hidden = 7168usize;
    let rows = 128usize;
    println!("\n# fused quantized matvec: {rows} rows × {hidden} weights\n");
    for fmt in [QuantFormat::Q4K, QuantFormat::Q3K] {
        let mut rng = Pcg::new(0xD07 + fmt.block_bytes() as u64);
        let wdata: Vec<f32> = (0..rows * hidden).map(|_| rng.next_normal() * 0.05).collect();
        let x: Vec<f32> = (0..hidden).map(|_| rng.next_normal()).collect();
        let mut packed = vec![0u8; fmt.row_bytes(rows * hidden)?];
        quant::quantize_into_with(fmt, &wdata, None, &mut packed, cores)?;
        let packed_bytes = packed.len() as u64;
        let mut out = vec![0f32; rows];
        let fused = Bench::new()
            .throughput_bytes(packed_bytes)
            .run(&format!("vec_dot_rows/{}", fmt.name()), || {
                quant::vec_dot_rows_with(fmt, &packed, &x, &mut out, 1).unwrap()
            });
        let fused_par = Bench::new()
            .throughput_bytes(packed_bytes)
            .run(&format!("vec_dot_rows-par{cores}/{}", fmt.name()), || {
                quant::vec_dot_rows_with(fmt, &packed, &x, &mut out, cores).unwrap()
            });
        let mut w = vec![0f32; rows * hidden];
        let dequant_dot = Bench::new()
            .throughput_bytes(packed_bytes)
            .run(&format!("dequant-then-dot/{}", fmt.name()), || {
                quant::dequantize_into_with(fmt, &packed, &mut w, 1).unwrap();
                for (o, row) in out.iter_mut().zip(w.chunks_exact(hidden)) {
                    *o = kernels::dot_lanes(row, &x);
                }
            });
        let speedup = dequant_dot.median_ns / fused.median_ns;
        println!(
            "matvec {:<5} fused beats dequantize-then-dot by {speedup:.2}x \
             (parallel fused: {:.2}x over serial fused)",
            fmt.name(),
            fused.median_ns / fused_par.median_ns,
        );
        decode_report.push(result_json(&fused));
        decode_report.push(result_json(&fused_par));
        decode_report.push(result_json(&dequant_dot));
        decode_summary.push((format!("vecdot_vs_dequant_dot_{}", fmt.name()), speedup));
    }

    // --- the acceptance benchmark: multi-tensor Q4_K container ---
    // Serial (1 thread) vs tensor-parallel (all cores) quantization of a
    // deterministic tiny-moe f32 checkpoint under the pure-Q4_K scheme.
    // On a multi-core host the parallel path must be ≥2× faster; both
    // paths produce byte-identical containers (verified below).
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 99)?;
    let scheme = builtin::scheme("q4_k")?;
    println!(
        "\n# container quantization: {} tensors, {:.1} MiB f32, scheme q4_k",
        src.tensors.len(),
        src.data_bytes() as f64 / (1 << 20) as f64
    );

    let time_best_of = |threads: usize, reps: usize| -> anyhow::Result<(f64, Vec<u8>)> {
        let mut best = f64::INFINITY;
        let mut bytes = Vec::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            let out = quantize_container_with(&src, &scheme, None, threads)?;
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
            }
            bytes = out.to_bytes();
        }
        Ok((best, bytes))
    };
    let (serial_s, serial_bytes) = time_best_of(1, 3)?;
    let (par_s, par_bytes) = time_best_of(cores, 3)?;
    assert_eq!(serial_bytes, par_bytes, "parallel container must be byte-identical");
    println!(
        "bench container-quantize/q4_k/serial        {serial_s:>8.3} s\n\
         bench container-quantize/q4_k/parallel-{cores:<3} {par_s:>8.3} s\n\
         speedup: {:.2}x on {cores} cores (byte-identical output)",
        serial_s / par_s
    );
    summary.push(("container_q4k_serial_s".to_string(), serial_s));
    summary.push(("container_q4k_parallel_s".to_string(), par_s));
    summary.push(("container_q4k_speedup".to_string(), serial_s / par_s));

    // --- whole-container decode under the paper's DQ3_K_M recipe: the
    // mixed q6_k/q4_k/q3_k payloads the serving loader actually walks,
    // decoded tensor by tensor on each pinned arm.
    let dq3 = Container::from_bytes(
        quantize_container_with(&src, &builtin::scheme("dq3_k_m")?, None, cores)?.to_bytes(),
    )?;
    let total_weights: usize = dq3.tensors.iter().map(|t| t.n_elems()).sum();
    let max_weights = dq3.tensors.iter().map(|t| t.n_elems()).max().unwrap_or(0);
    let mut scratch = vec![0f32; max_weights];
    println!(
        "\n# container decode: dq3_k_m tiny-moe ({} tensors, {total_weights} weights)\n",
        dq3.tensors.len()
    );
    let bytes = (total_weights * 4) as u64;
    let mut arm_results = Vec::new();
    for (arm, label) in [(false, "scalar"), (true, "lanes")] {
        let r = Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("container-decode-{label}/dq3_k_m"), || {
                for t in &dq3.tensors {
                    kernels::decode_blocks_pinned(
                        t.format,
                        dq3.bytes(t),
                        &mut scratch[..t.n_elems()],
                        arm,
                    );
                }
            });
        arm_results.push(r);
    }
    let dq3_speedup = arm_results[0].median_ns / arm_results[1].median_ns;
    println!(
        "decode dq3_k_m container: scalar {:>6.2} GiB/s → lanes {:>6.2} GiB/s  ({dq3_speedup:.2}x)",
        gibps(bytes, &arm_results[0]),
        gibps(bytes, &arm_results[1]),
    );
    for r in &arm_results {
        decode_report.push(result_json(r));
    }
    decode_summary.push(("decode_dq3_k_m_speedup".to_string(), dq3_speedup));

    // --- GGUF import throughput (PR 9): transcoding a llama.cpp-layout
    // checkpoint into the DSQ1 container — the `dsq import` hot path
    // (per-tensor bit-permutation + census reorder), serial vs
    // tensor-parallel. Source bytes come from exporting a q4_k_m
    // tiny-dense container, so the measured work is exactly the
    // from-llama transcode the importer runs on real checkpoints.
    let dense = Container::from_bytes(
        quantize_container_with(
            &synthetic_f32_container(&ModelConfig::tiny_dense(), 0x601D)?,
            &builtin::scheme("q4_k_m")?,
            None,
            cores,
        )?
        .to_bytes(),
    )?;
    let gguf_bytes = gguf::export_bytes(&dense)?;
    let g = gguf::Gguf::from_bytes(&gguf_bytes)?;
    let gguf_len = gguf_bytes.len() as u64;
    println!(
        "\n# gguf import: q4_k_m tiny-dense ({} tensors, {:.1} MiB)\n",
        g.tensors.len(),
        gguf_len as f64 / (1 << 20) as f64
    );
    let mut import_results = Vec::new();
    for (threads, label) in [(1usize, "serial"), (cores, "parallel")] {
        let r = Bench::new().throughput_bytes(gguf_len).run(
            &format!("gguf-import-{label}/q4_k_m"),
            || gguf::import_gguf(&g, threads).unwrap().to_bytes().len(),
        );
        import_results.push(r);
    }
    let import_speedup = import_results[0].median_ns / import_results[1].median_ns;
    println!(
        "gguf import q4_k_m: serial {:>6.2} GiB/s → parallel-{cores} {:>6.2} GiB/s  \
         ({import_speedup:.2}x)",
        gibps(gguf_len, &import_results[0]),
        gibps(gguf_len, &import_results[1]),
    );
    for r in &import_results {
        decode_report.push(result_json(r));
    }
    decode_summary.push(("gguf_import_parallel_speedup".to_string(), import_speedup));

    // --- native forward pass (PR 4, dense since PR 5): tokens/s
    // through the full step on encoded weights — the MLA+MoE tiny-moe
    // and the dense-GQA tiny-dense (Table 5) proxies, prefilling an
    // 8-token prompt and greedily decoding 8 more, per scheme, serial
    // vs row-parallel matvecs. This is the `dsq eval --native`
    // per-token cost. Alongside the throughput, the bench counts heap
    // allocation events: prefill pays a handful (the lazy per-slot KV
    // buffer), decode must be allocation-free (scratch reuse).
    println!("\n# native forward pass: prefill(8) + greedy decode(8), both model kinds\n");
    let prompt = [1i32, 17, 300, 42, 511, 7, 5, 260];
    let decode_steps = 8usize;
    let total_tokens = (prompt.len() + decode_steps) as f64;
    let dense_src = synthetic_f32_container(&ModelConfig::tiny_dense(), 99)?;
    for (model_tag, model_src) in [("", &src), ("tiny_dense/", &dense_src)] {
        for scheme_name in ["dq3_k_m", "q4_k_m"] {
            let qbytes =
                quantize_container_with(model_src, &builtin::scheme(scheme_name)?, None, cores)?
                    .to_bytes();
            let mut tok_s = Vec::new();
            // On a 1-core host the parallel arm is the serial arm — skip
            // the duplicate measurement (and the meaningless speedup row).
            let mut thread_counts = vec![1usize];
            if cores > 1 {
                thread_counts.push(cores);
            }
            // Summary keys: tiny-moe keeps its PR-4 names so the perf
            // trajectory stays comparable; tiny-dense rows are new.
            let key = |suffix: &str| {
                format!("forward_{}{scheme_name}_{suffix}", model_tag.replace('/', "_"))
            };
            let mut fwd = ForwardPass::new(Container::from_bytes(qbytes)?, 1, NATIVE_MAX_CTX)?;
            for &threads in &thread_counts {
                fwd.set_mode(MatvecMode::Threads(threads));
                let mut logits = vec![0f32; fwd.vocab()];
                let mut scratch = fwd.new_scratch();
                // `quick` preset: one iteration is a whole 16-token wave.
                let r = Bench::quick().throughput_items(total_tokens as u64).run(
                    &format!("forward-tokens/{model_tag}{scheme_name}/threads{threads}"),
                    || {
                        let mut cache = fwd.new_cache();
                        for (j, &t) in prompt.iter().enumerate() {
                            let want =
                                if j + 1 == prompt.len() { Some(&mut logits[..]) } else { None };
                            fwd.forward_token(t, &mut cache, &mut scratch, want).unwrap();
                        }
                        for _ in 0..decode_steps {
                            let tok = dsq::coordinator::sampler::argmax(&logits);
                            fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits))
                                .unwrap();
                        }
                        logits[0]
                    },
                );
                let tps = total_tokens / (r.median_ns / 1e9);
                println!(
                    "forward {model_tag}{scheme_name:<8} threads {threads:>2}: \
                     {tps:>8.1} tokens/s ({:.2} ms/token)",
                    r.median_ns / 1e6 / total_tokens
                );
                forward_report.push(result_json(&r));
                forward_summary.push((key(&format!("t{threads}_tokens_per_s")), tps));
                tok_s.push(tps);
            }
            if tok_s.len() == 2 {
                forward_summary.push((key("parallel_speedup"), tok_s[1] / tok_s[0]));
            }
            // Allocation discipline, measured outside the timing loop:
            // prefill allocates once per slot (the lazy KV buffer);
            // each decoded token must allocate nothing.
            fwd.set_mode(MatvecMode::Threads(1));
            let mut cache = fwd.new_cache();
            let mut scratch = fwd.new_scratch();
            let mut logits = vec![0f32; fwd.vocab()];
            let a0 = ALLOC_EVENTS.load(Ordering::Relaxed);
            for (j, &t) in prompt.iter().enumerate() {
                let want = if j + 1 == prompt.len() { Some(&mut logits[..]) } else { None };
                fwd.forward_token(t, &mut cache, &mut scratch, want)?;
            }
            let prefill_allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - a0;
            let a1 = ALLOC_EVENTS.load(Ordering::Relaxed);
            for _ in 0..decode_steps {
                let tok = dsq::coordinator::sampler::argmax(&logits);
                fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits))?;
            }
            let decode_allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - a1;
            println!(
                "forward {model_tag}{scheme_name:<8} allocs: prefill {prefill_allocs} \
                 (lazy KV), decode {decode_allocs} over {decode_steps} tokens"
            );
            forward_summary.push((key("prefill_allocs"), prefill_allocs as f64));
            forward_summary.push((
                key("decode_allocs_per_token"),
                decode_allocs as f64 / decode_steps as f64,
            ));
        }
    }

    // --- panel prefill (PR 6): a 64-token prompt pushed through the
    // quantized-GEMM panel pass (`forward_tokens`) vs the per-token
    // baseline loop, per model kind and scheme, both in the serial
    // matvec mode so the comparison isolates decode-once panel reuse.
    // The acceptance bar is ≥3× prefill tokens/s; the two paths are
    // bit-identical (locked by tests/native_forward.rs), so the speedup
    // is pure arithmetic reuse, not a numerics trade.
    println!("\n# panel prefill: 64-token prompt, GEMM panel vs per-token loop\n");
    let prefill_len = 64usize;
    let mut rng_p = Pcg::new(0x6E64);
    let long_prompt: Vec<i32> =
        (0..prefill_len).map(|_| (rng_p.next_u64() % 512) as i32).collect();
    for (model_tag, model_src) in [("", &src), ("tiny_dense/", &dense_src)] {
        for scheme_name in ["dq3_k_m", "q4_k_m"] {
            let qbytes =
                quantize_container_with(model_src, &builtin::scheme(scheme_name)?, None, cores)?
                    .to_bytes();
            let fwd = ForwardPass::new(Container::from_bytes(qbytes)?, 1, prefill_len + 8)?;
            let key = |suffix: &str| {
                format!("prefill_{}{scheme_name}_{suffix}", model_tag.replace('/', "_"))
            };
            let mut logits = vec![0f32; fwd.vocab()];
            let mut scratch = fwd.new_scratch();
            let token_loop = Bench::quick().throughput_items(prefill_len as u64).run(
                &format!("prefill-token-loop/{model_tag}{scheme_name}"),
                || {
                    let mut cache = fwd.new_cache();
                    for (j, &t) in long_prompt.iter().enumerate() {
                        let want =
                            if j + 1 == prefill_len { Some(&mut logits[..]) } else { None };
                        fwd.forward_token(t, &mut cache, &mut scratch, want).unwrap();
                    }
                    logits[0]
                },
            );
            let panel = Bench::quick().throughput_items(prefill_len as u64).run(
                &format!("prefill-panel/{model_tag}{scheme_name}"),
                || {
                    let mut cache = fwd.new_cache();
                    fwd.forward_tokens(&long_prompt, &mut cache, &mut scratch, Some(&mut logits))
                        .unwrap();
                    logits[0]
                },
            );
            let tps_loop = prefill_len as f64 / (token_loop.median_ns / 1e9);
            let tps_panel = prefill_len as f64 / (panel.median_ns / 1e9);
            let speedup = token_loop.median_ns / panel.median_ns;
            println!(
                "prefill {model_tag}{scheme_name:<8}: token loop {tps_loop:>8.1} tok/s → \
                 panel {tps_panel:>8.1} tok/s  ({speedup:.2}x)"
            );
            forward_report.push(result_json(&token_loop));
            forward_report.push(result_json(&panel));
            forward_summary.push((key("token_loop_tokens_per_s"), tps_loop));
            forward_summary.push((key("panel_tokens_per_s"), tps_panel));
            forward_summary.push((key("panel_speedup"), speedup));
        }
    }

    // Decode + forward measurements ride the main report too.
    report.extend(decode_report.iter().cloned());
    summary.extend(decode_summary.iter().cloned());
    report.extend(forward_report.iter().cloned());
    summary.extend(forward_summary.iter().cloned());

    if let Some(path) = json_decode_path {
        let fields: Vec<(&str, json::Value)> = decode_summary
            .iter()
            .map(|(k, v)| (k.as_str(), json::num(*v)))
            .collect();
        let doc = json::obj(vec![
            ("bench", json::str_("codec-decode")),
            ("cores", json::num(cores as f64)),
            ("weights_per_iter", json::num(n as f64)),
            ("results", json::Value::Arr(decode_report.clone())),
            ("summary", json::obj(fields)),
        ]);
        std::fs::write(&path, json::to_string_pretty(&doc))?;
        eprintln!("wrote decode bench JSON → {path}");
    }

    if let Some(path) = json_forward_path {
        let fields: Vec<(&str, json::Value)> = forward_summary
            .iter()
            .map(|(k, v)| (k.as_str(), json::num(*v)))
            .collect();
        let doc = json::obj(vec![
            ("bench", json::str_("codec-forward")),
            ("cores", json::num(cores as f64)),
            ("prompt_tokens", json::num(prompt.len() as f64)),
            ("decode_tokens", json::num(decode_steps as f64)),
            ("panel_prompt_tokens", json::num(prefill_len as f64)),
            ("results", json::Value::Arr(forward_report.clone())),
            ("summary", json::obj(fields)),
        ]);
        std::fs::write(&path, json::to_string_pretty(&doc))?;
        eprintln!("wrote forward bench JSON → {path}");
    }

    if let Some(path) = json_path {
        let summary_fields: Vec<(&str, json::Value)> = summary
            .iter()
            .map(|(k, v)| (k.as_str(), json::num(*v)))
            .collect();
        let doc = json::obj(vec![
            ("bench", json::str_("codec")),
            ("cores", json::num(cores as f64)),
            ("weights_per_iter", json::num(n as f64)),
            ("results", json::Value::Arr(report)),
            ("summary", json::obj(summary_fields)),
        ]);
        std::fs::write(&path, json::to_string_pretty(&doc))?;
        eprintln!("wrote bench JSON → {path}");
    }
    Ok(())
}
