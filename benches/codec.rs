//! Codec micro-benchmarks: quantize + dequantize throughput per format
//! through the zero-copy `BlockCodec` entry points, serial vs
//! block-parallel, with and without importance weighting — plus the
//! headline container benchmark: multi-tensor Q4_K container
//! quantization, serial vs tensor-parallel (the `dsq quantize` hot
//! path; the serving hot path dequantizes inside XLA).

use dsq::container::{quantize_container_with, synthetic_f32_container};
use dsq::model::ModelConfig;
use dsq::quant::{self, parallel, QuantFormat};
use dsq::scheme::builtin;
use dsq::util::bench::Bench;
use dsq::util::rng::Pcg;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n = 256 * 1024; // 256K weights ≈ a large expert matrix slice
    let mut rng = Pcg::new(1);
    let data: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
    let importance: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
    let cores = parallel::max_threads();

    println!("# codec throughput, {n} weights/iter, {cores} cores\n");
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ] {
        let bytes = (n * 4) as u64;
        let mut packed = vec![0u8; fmt.row_bytes(n)?];
        Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("quantize-serial/{}", fmt.name()), || {
                quant::quantize_into_with(fmt, &data, None, &mut packed, 1).unwrap()
            });
        Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("quantize-par{cores}/{}", fmt.name()), || {
                quant::quantize_into_with(fmt, &data, None, &mut packed, cores).unwrap()
            });
        // Pinned to 1 thread so the imatrix overhead reads directly
        // against the quantize-serial row above.
        Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("quantize-imatrix-serial/{}", fmt.name()), || {
                quant::quantize_into_with(fmt, &data, Some(&importance), &mut packed, 1).unwrap()
            });
        quant::quantize_into(fmt, &data, None, &mut packed)?;
        let mut decoded = vec![0f32; n];
        Bench::new()
            .throughput_bytes(bytes)
            .run(&format!("dequantize/{}", fmt.name()), || {
                quant::dequantize_into(fmt, &packed, &mut decoded).unwrap()
            });
    }

    // --- the acceptance benchmark: multi-tensor Q4_K container ---
    // Serial (1 thread) vs tensor-parallel (all cores) quantization of a
    // deterministic tiny-moe f32 checkpoint under the pure-Q4_K scheme.
    // On a multi-core host the parallel path must be ≥2× faster; both
    // paths produce byte-identical containers (verified below).
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 99)?;
    let scheme = builtin::scheme("q4_k")?;
    println!(
        "\n# container quantization: {} tensors, {:.1} MiB f32, scheme q4_k",
        src.tensors.len(),
        src.data_bytes() as f64 / (1 << 20) as f64
    );

    let time_best_of = |threads: usize, reps: usize| -> anyhow::Result<(f64, Vec<u8>)> {
        let mut best = f64::INFINITY;
        let mut bytes = Vec::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            let out = quantize_container_with(&src, &scheme, None, threads)?;
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
            }
            bytes = out.to_bytes();
        }
        Ok((best, bytes))
    };
    let (serial_s, serial_bytes) = time_best_of(1, 3)?;
    let (par_s, par_bytes) = time_best_of(cores, 3)?;
    assert_eq!(serial_bytes, par_bytes, "parallel container must be byte-identical");
    println!(
        "bench container-quantize/q4_k/serial        {serial_s:>8.3} s\n\
         bench container-quantize/q4_k/parallel-{cores:<3} {par_s:>8.3} s\n\
         speedup: {:.2}x on {cores} cores (byte-identical output)",
        serial_s / par_s
    );
    Ok(())
}
