"""AOT pipeline: lower the L2 model to HLO *text* artifacts.

For every (model, scheme) variant the paper's tables need, this emits:

- ``artifacts/hlo/{model}_{scheme}_prefill.hlo.txt``
- ``artifacts/hlo/{model}_{scheme}_decode.hlo.txt``
- matching ``.manifest.json`` files describing the exact input/output
  order, shapes, dtypes, and per-weight quant formats, which the Rust
  runtime (`rust/src/runtime/`) uses to marshal buffers.

HLO **text** (not serialized protos) is the interchange format — the
image's xla_extension 0.5.1 rejects jax≥0.5 64-bit-id protos; the text
parser reassigns ids (see /opt/xla-example/README.md).

Weights are *runtime inputs*: quantized tensors enter as packed uint8
``[rows, row_bytes]`` buffers streamed straight from the `.dsq`
container — Python never touches the request path.

Usage: ``python -m compile.aot --out ../artifacts/hlo [--only tiny-moe_f32]``
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, quants, schemes, tasks

BATCH = 16
PROMPT_LEN = tasks.MAX_PROMPT  # 16
MAX_CTX = tasks.SEQ_LEN  # 24

# (model, scheme) variants required by Tables 2-5.
VARIANTS: list[tuple[str, str]] = [
    *[("tiny-moe", s) for s in
      ["f32", "q4_k_m", "q3_k_m", "dq3_k_m", "q2_k_l", "ud_q2_k_xl", "q4_k", "q3_k"]],
    *[("tiny-dense", s) for s in ["f32", "q8_0", "q4_k_m", "q3_k_m"]],
]


def weight_specs(cfg: model.Config, scheme_name: str):
    """Per-weight (name, class, fmt, logical shape, buffer shape/dtype)."""
    scheme = schemes.load_scheme(scheme_name)
    specs = []
    for name, cls, layer, shape in model.census(cfg):
        row_len = shape[-1]
        n_params = 1
        for d in shape:
            n_params *= d
        fmt = schemes.assign(scheme, cls, layer, row_len, n_params, cfg)
        if fmt == "f32":
            buf_shape, dtype = tuple(shape), "f32"
        else:
            rows = n_params // row_len
            buf_shape, dtype = (rows, quants.row_bytes(fmt, row_len)), "u8"
        specs.append(dict(name=name, cls=cls, layer=layer, fmt=fmt,
                          shape=tuple(shape), buf_shape=buf_shape, dtype=dtype))
    return specs


def _abstract(spec):
    dt = {"f32": jnp.float32, "u8": jnp.uint8, "i32": jnp.int32}[spec["dtype"]]
    return jax.ShapeDtypeStruct(spec["buf_shape"], dt)


def _weights_from_args(cfg, specs, args):
    weights = {}
    for spec, arr in zip(specs, args):
        weights[spec["name"]] = model.WeightTensor(spec["fmt"], arr, spec["shape"])
    return weights


def build_fns(cfg: model.Config, scheme_name: str):
    specs = weight_specs(cfg, scheme_name)

    def prefill(tokens, lengths, *wargs):
        weights = _weights_from_args(cfg, specs, wargs)
        logits, cache = model.forward_prefill(cfg, weights, tokens, lengths, MAX_CTX)
        if cfg.kind == "mla_moe":
            return (logits, cache)
        return (logits, cache[0], cache[1])

    def decode(token, pos, *rest):
        if cfg.kind == "mla_moe":
            cache = rest[0]
            wargs = rest[1:]
        else:
            cache = (rest[0], rest[1])
            wargs = rest[2:]
        weights = _weights_from_args(cfg, specs, wargs)
        logits, out_cache = model.forward_decode(cfg, weights, token, pos, cache)
        if cfg.kind == "mla_moe":
            return (logits, out_cache)
        return (logits, out_cache[0], out_cache[1])

    return specs, prefill, decode


def cache_specs(cfg: model.Config):
    if cfg.kind == "mla_moe":
        return [dict(role="cache_kv",
                     buf_shape=(cfg.n_layers, BATCH, MAX_CTX, cfg.kv_dim()),
                     dtype="f32")]
    kd = cfg.n_kv_heads * cfg.head_dim
    return [
        dict(role="cache_k", buf_shape=(cfg.n_layers, BATCH, MAX_CTX, kd), dtype="f32"),
        dict(role="cache_v", buf_shape=(cfg.n_layers, BATCH, MAX_CTX, kd), dtype="f32"),
    ]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(model_name: str, scheme_name: str, outdir: Path):
    cfg = model.Config.load(model_name)
    specs, prefill, decode = build_fns(cfg, scheme_name)
    w_abs = [_abstract(s) for s in specs]
    caches = cache_specs(cfg)
    c_abs = [jax.ShapeDtypeStruct(c["buf_shape"], jnp.float32) for c in caches]

    for phase in ("prefill", "decode"):
        t0 = time.time()
        if phase == "prefill":
            args = [
                jax.ShapeDtypeStruct((BATCH, PROMPT_LEN), jnp.int32),
                jax.ShapeDtypeStruct((BATCH,), jnp.int32),
                *w_abs,
            ]
            lowered = jax.jit(prefill).lower(*args)
            inputs = (
                [dict(role="tokens", buf_shape=(BATCH, PROMPT_LEN), dtype="i32"),
                 dict(role="lengths", buf_shape=(BATCH,), dtype="i32")]
                + [dict(role="weight", name=s["name"], format=s["fmt"],
                        buf_shape=s["buf_shape"], dtype=s["dtype"]) for s in specs]
            )
        else:
            args = [
                jax.ShapeDtypeStruct((BATCH,), jnp.int32),
                jax.ShapeDtypeStruct((BATCH,), jnp.int32),
                *c_abs,
                *w_abs,
            ]
            lowered = jax.jit(decode).lower(*args)
            inputs = (
                [dict(role="token", buf_shape=(BATCH,), dtype="i32"),
                 dict(role="pos", buf_shape=(BATCH,), dtype="i32")]
                + caches
                + [dict(role="weight", name=s["name"], format=s["fmt"],
                        buf_shape=s["buf_shape"], dtype=s["dtype"]) for s in specs]
            )
        outputs = [dict(role="logits", buf_shape=(BATCH, cfg.vocab_size), dtype="f32")] + caches

        stem = f"{model_name}_{scheme_name}_{phase}"
        text = to_hlo_text(lowered)
        (outdir / f"{stem}.hlo.txt").write_text(text)
        manifest = dict(
            model=cfg.to_dict(), scheme=scheme_name, phase=phase,
            batch=BATCH, prompt_len=PROMPT_LEN, max_ctx=MAX_CTX,
            vocab=cfg.vocab_size,
            inputs=[_jsonable(i) for i in inputs],
            outputs=[_jsonable(o) for o in outputs],
        )
        (outdir / f"{stem}.manifest.json").write_text(json.dumps(manifest, indent=1))
        print(f"[aot] {stem}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s",
              flush=True)


def _jsonable(d):
    d = dict(d)
    d["buf_shape"] = list(d["buf_shape"])
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/hlo")
    ap.add_argument("--only", default=None,
                    help="comma-separated '{model}_{scheme}' stems to build")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    for model_name, scheme_name in VARIANTS:
        stem = f"{model_name}_{scheme_name}"
        if only is not None and stem not in only:
            continue
        lower_variant(model_name, scheme_name, outdir)


if __name__ == "__main__":
    main()
