"""Build-time training of the four proxy checkpoints (DESIGN.md §2).

Checkpoints (written as f32 `.dsq` containers to ``artifacts/ckpt/``):

- ``r1``      — tiny-moe, reasoning-heavy mixture (DeepSeek-R1 proxy).
- ``v3``      — tiny-moe, balanced mixture (DeepSeek-V3 proxy).
- ``v3_0324`` — the v3 run continued for 50% more steps (the 0324
  checkpoint refresh).
- ``distill`` — tiny-dense trained by *distillation*: prompts from the
  r1 mixture, targets sampled greedily from the trained r1 model
  (§2.1's data-driven distillation, in miniature).

Pure-JAX Adam (no optax in this environment). Deterministic: fixed
seeds, fixed data streams (tasks.Pcg).

Usage: ``python -m compile.train --out ../artifacts/ckpt [--steps N]``
"""

from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import container, model, tasks

BATCH = 32
SEQ = tasks.SEQ_LEN  # 24


def make_batch(mixture, rng: tasks.Pcg, batch=BATCH):
    toks = np.zeros((batch, SEQ), np.int32)
    mask = np.zeros((batch, SEQ), np.float32)
    for b in range(batch):
        q = tasks.train_sample(mixture, rng)
        t, m = tasks.pad_example(q)
        toks[b], mask[b] = t, m
    return jnp.asarray(toks), jnp.asarray(mask)


def loss_fn(params, cfg, tokens, mask):
    weights = {k: model.WeightTensor("f32", v, v.shape) for k, v in params.items()}
    logits = model.forward_train(cfg, weights, tokens)
    # Predict token t+1 from position t.
    targets = tokens[:, 1:]
    lmask = mask[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * lmask) / jnp.maximum(jnp.sum(lmask), 1.0)


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1, 2))
def train_step(params, m_state, v_state, step, cfg, tokens, mask, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens, mask)
    b1, b2, eps = 0.9, 0.95, 1e-8
    new_p, new_m, new_v = {}, {}, {}
    t = step + 1.0
    for k in params:
        g = grads[k]
        m = b1 * m_state[k] + (1 - b1) * g
        v = b2 * v_state[k] + (1 - b2) * g * g
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m, v
    return new_p, new_m, new_v, loss


def train(cfg: model.Config, mixture, steps: int, seed: int, lr=3e-3, params=None,
          batch_fn=None, log_every=50, tag=""):
    if params is None:
        params = {k: w.data for k, w in model.init_weights(cfg, seed).items()}
    m_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    v_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    rng = tasks.Pcg(tasks.TRAIN_SEED ^ seed)
    losses = []
    t0 = time.time()
    hcfg = HashableConfig(cfg)
    for step in range(steps):
        if batch_fn is not None:
            tokens, mask = batch_fn(step)
        else:
            tokens, mask = make_batch(mixture, rng)
        # Cosine LR decay with short warmup.
        warm = min(1.0, (step + 1) / 30)
        decay = 0.5 * (1 + np.cos(np.pi * step / max(steps, 1)))
        cur_lr = lr * warm * (0.1 + 0.9 * decay)
        params, m_state, v_state, loss = train_step(
            params, m_state, v_state, float(step), hcfg, tokens, mask, cur_lr
        )
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(
                f"[train{tag}] step {step:4d} loss {float(loss):.4f} "
                f"lr {cur_lr:.2e} ({time.time()-t0:.0f}s)",
                flush=True,
            )
    return params, losses


class HashableConfig:
    """jit static wrapper for model.Config."""

    def __init__(self, cfg: model.Config):
        self.cfg = cfg
        self._key = tuple(sorted(cfg.to_dict().items()))

    def __getattr__(self, k):
        return getattr(self.cfg, k)

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, HashableConfig) and self._key == other._key


# ---------------------------------------------------------------------------
# Distillation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "max_ctx"))
def _prefill_jit(cfg, params, tokens, lengths, max_ctx):
    weights = {k: model.WeightTensor("f32", v, v.shape) for k, v in params.items()}
    return model.forward_prefill(cfg.cfg, weights, tokens, lengths, max_ctx)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_jit(cfg, params, token, pos, cache):
    weights = {k: model.WeightTensor("f32", v, v.shape) for k, v in params.items()}
    return model.forward_decode(cfg.cfg, weights, token, pos, cache)


def teacher_generate(cfg, params, prompts, lengths, max_new=tasks.MAX_ANSWER):
    """Greedy generation from the teacher. prompts [B, T], lengths [B]."""
    hcfg = HashableConfig(cfg)
    b, t = prompts.shape
    max_ctx = t + max_new
    logits, cache = _prefill_jit(hcfg, params, jnp.asarray(prompts), jnp.asarray(lengths), max_ctx)
    outs = [[] for _ in range(b)]
    done = np.zeros(b, bool)
    pos = np.asarray(lengths).copy()
    for _ in range(max_new):
        tok = np.asarray(jnp.argmax(logits, axis=-1))
        for i in range(b):
            if not done[i]:
                outs[i].append(int(tok[i]))
                if tok[i] == tasks.EOS:
                    done[i] = True
        if done.all():
            break
        logits, cache = _decode_jit(hcfg, params, jnp.asarray(tok), jnp.asarray(pos), cache)
        pos = pos + 1
    return outs


def make_distill_batch(teacher_cfg, teacher_params, mixture, rng, batch=BATCH):
    """Prompts from the mixture; targets = teacher's greedy outputs."""
    qs = [tasks.train_sample(mixture, rng) for _ in range(batch)]
    t = tasks.MAX_PROMPT
    prompts = np.zeros((batch, t), np.int32)
    lengths = np.zeros(batch, np.int32)
    for i, q in enumerate(qs):
        prompts[i, : len(q.prompt)] = q.prompt
        lengths[i] = len(q.prompt)
    outs = teacher_generate(teacher_cfg, teacher_params, prompts, lengths)
    toks = np.zeros((batch, SEQ), np.int32)
    mask = np.zeros((batch, SEQ), np.float32)
    for i, q in enumerate(qs):
        ans = outs[i][: tasks.MAX_ANSWER]
        seqt = q.prompt + ans
        toks[i, : len(seqt)] = seqt
        mask[i, len(q.prompt) : len(seqt)] = 1.0
    return jnp.asarray(toks), jnp.asarray(mask)


# ---------------------------------------------------------------------------
# Checkpoint IO
# ---------------------------------------------------------------------------


def save_checkpoint(cfg: model.Config, params, path: Path, meta: dict):
    w = container.Writer(model=cfg.to_dict(), scheme="f32", meta=meta)
    for name, cls, layer, _shape in model.census(cfg):
        w.add(name, cls, layer, np.asarray(params[name]))
    path.parent.mkdir(parents=True, exist_ok=True)
    w.write(path)
    print(f"[train] wrote {path} ({path.stat().st_size/1e6:.1f} MB)")


def load_checkpoint(path: Path) -> dict:
    """Read an f32 .dsq back into a params dict (jnp arrays)."""
    import jax.numpy as jnp

    c = container.Container.open(path)
    return {e.name: jnp.asarray(c.dequantize(e)) for e in c.entries}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/ckpt")
    ap.add_argument("--steps", type=int, default=900)
    ap.add_argument("--extra-steps", type=int, default=None,
                    help="v3_0324 continuation steps (default steps//2)")
    ap.add_argument("--distill-steps", type=int, default=450)
    ap.add_argument("--only", default=None, help="train a single checkpoint")
    ap.add_argument("--skip", default="", help="comma-separated checkpoints to skip")
    args = ap.parse_args()
    out = Path(args.out)
    skip = set(args.skip.split(",")) if args.skip else set()

    moe = model.Config.load("tiny-moe")
    dense = model.Config.load("tiny-dense")

    def want(name):
        if name in skip:
            return False
        return args.only is None or args.only == name

    r1_params = None
    if want("r1") or want("distill"):
        existing = out / "r1.f32.dsq"
        if "r1" in skip and existing.exists():
            print("=== loading existing r1 checkpoint (teacher) ===", flush=True)
            r1_params = load_checkpoint(existing)
        else:
            print("=== training r1 proxy (tiny-moe, reasoning-heavy) ===", flush=True)
            r1_params, losses = train(moe, tasks.MIXTURES["r1"], args.steps, seed=101, tag=":r1")
            save_checkpoint(
                moe, r1_params, out / "r1.f32.dsq",
                {"proxy_for": "DeepSeek-R1", "steps": args.steps, "seed": 101,
                 "final_loss": round(float(np.mean(losses[-20:])), 4)},
            )

    if want("v3") or want("v3_0324"):
        print("=== training v3 proxy (tiny-moe, balanced) ===", flush=True)
        v3_params, losses = train(moe, tasks.MIXTURES["v3"], args.steps, seed=202, tag=":v3")
        if want("v3"):
            save_checkpoint(
                moe, v3_params, out / "v3.f32.dsq",
                {"proxy_for": "DeepSeek-V3", "steps": args.steps, "seed": 202,
                 "final_loss": round(float(np.mean(losses[-20:])), 4)},
            )
        if want("v3_0324"):
            print("=== continuing v3 → v3-0324 (extra steps) ===", flush=True)
            extra = args.extra_steps if args.extra_steps is not None else args.steps // 2
            v3b_params, losses = train(
                moe, tasks.MIXTURES["v3_0324"], extra, seed=203, params=v3_params, tag=":v3_0324"
            )
            save_checkpoint(
                moe, v3b_params, out / "v3_0324.f32.dsq",
                {"proxy_for": "DeepSeek-V3-0324", "steps": args.steps + extra, "seed": 203,
                 "final_loss": round(float(np.mean(losses[-20:])), 4)},
            )

    if want("distill"):
        print("=== distilling r1 → tiny-dense ===", flush=True)
        rng = tasks.Pcg(tasks.TRAIN_SEED ^ 404)
        batch_fn = lambda step: make_distill_batch(moe, r1_params, tasks.MIXTURES["r1"], rng)
        d_params, losses = train(
            dense, None, args.distill_steps, seed=404, batch_fn=batch_fn, tag=":distill"
        )
        save_checkpoint(
            dense, d_params, out / "distill.f32.dsq",
            {"proxy_for": "DeepSeek-R1-distill-Qwen-32B", "steps": args.distill_steps,
             "seed": 404, "teacher": "r1",
             "final_loss": round(float(np.mean(losses[-20:])), 4)},
        )


if __name__ == "__main__":
    main()
