"""Synthetic benchmark task generators — the proxy suites of DESIGN.md §7.

This module is mirrored *exactly* by ``rust/src/eval/tasks.rs``: the same
splitmix64 RNG, the same token vocabulary, the same renderings. The
Python side generates training data (and the distillation corpus); the
Rust side regenerates the identical evaluation questions. Golden tests
on both sides pin the sequences.

## Token vocabulary (512 ids)

====== =============================
0      PAD
1      BOS
2      SEP
3      ANS    (generation starts after this)
4      EOS
5–14   digits 0–9
15–18  choice letters A–D
19–24  transform ops: SORT REV INC DEC MAX MIN
25–26  arithmetic ops: ADD SUB
64–191 entities (128; questions use 32 subjects)
320–351 relations (32; knowledge domain d ∈ {1,2,3,4} owns 8)
====== =============================

## Task families

- ``arith``       (MATH-500 proxy):   ``a ± b mod 100`` → 2 digits.
- ``arith_chain`` (AIME proxy):       ``((a±b)±c)±d mod 100`` → 2 digits.
- ``knowledge``   (GPQA/MMLU/CMMLU/C-Eval proxies): 4-way MC over a
  deterministic relation KB; domains are disjoint relation spaces.
- ``transform``   (MBPP/MBPP+ proxy): apply one op to 4–6 digits.
- ``transform_hard`` (LiveCodeBench proxy): two composed ops.

Answers always terminate with EOS. MBPP scores prefix-leniently; MBPP+
requires exact-match including EOS (the "stricter tests" of the paper).
"""

from __future__ import annotations

from dataclasses import dataclass

MASK64 = (1 << 64) - 1

# --- token ids (mirror: rust/src/eval/tasks.rs) ---
PAD, BOS, SEP, ANS, EOS = 0, 1, 2, 3, 4
DIG0 = 5  # digits 0-9 → 5..14
CH_A = 15  # choices A-D → 15..18
OP_SORT, OP_REV, OP_INC, OP_DEC, OP_MAX, OP_MIN = 19, 20, 21, 22, 23, 24
OP_ADD, OP_SUB = 25, 26
ENT0, N_ENT = 64, 128
N_SUBJ = 32
REL0, RELS_PER_DOMAIN = 320, 8
VOCAB = 512

KB_SEED = 0xDEE9_5EED
TRAIN_SEED = 1234
EVAL_SEED = 777

TRANSFORM_OPS = [OP_SORT, OP_REV, OP_INC, OP_DEC, OP_MAX, OP_MIN]


class Pcg:
    """splitmix64 — exact mirror of ``rust/src/util/rng.rs::Pcg``."""

    def __init__(self, seed: int):
        self.state = (seed + 0x9E3779B97F4A7C15) & MASK64

    def derive(self, label: int) -> "Pcg":
        child = Pcg(self.state ^ ((label * 0xD1342543DE82EF95) & MASK64))
        child.next_u64()
        return child

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def next_below(self, bound: int) -> int:
        return (self.next_u64() * bound) >> 64

    def next_f32(self) -> float:
        return (self.next_u64() >> 40) / (1 << 24)

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) / (1 << 53)


def fnv1a(s: str) -> int:
    """Suite-name → substream id (mirror of Suite::stream_id)."""
    h = 0xCBF29CE484222325
    for b in s.encode():
        h = ((h ^ b) * 0x100000001B3) & MASK64
    return h


def kb_answer(domain: int, subj: int, rel: int) -> int:
    """Deterministic KB: entity index answering (subject, relation)."""
    r = Pcg(KB_SEED ^ (domain << 40) ^ (subj << 20) ^ rel)
    return r.next_below(N_ENT)


def _digits2(v: int) -> list[int]:
    return [DIG0 + (v // 10) % 10, DIG0 + v % 10]


@dataclass
class Question:
    """A rendered task instance."""

    prompt: list[int]  # ends with ANS
    answer: list[int]  # ends with EOS


def gen_arith(rng: Pcg) -> Question:
    a, b = rng.next_below(100), rng.next_below(100)
    op = OP_ADD if rng.next_below(2) == 0 else OP_SUB
    c = (a + b) % 100 if op == OP_ADD else (a - b) % 100
    return Question([BOS, *_digits2(a), op, *_digits2(b), ANS], [*_digits2(c), EOS])


def gen_arith_chain(rng: Pcg) -> Question:
    vals = [rng.next_below(100) for _ in range(4)]
    ops = [OP_ADD if rng.next_below(2) == 0 else OP_SUB for _ in range(3)]
    acc = vals[0]
    prompt = [BOS, *_digits2(vals[0])]
    for v, op in zip(vals[1:], ops):
        acc = (acc + v) % 100 if op == OP_ADD else (acc - v) % 100
        prompt += [op, *_digits2(v)]
    prompt.append(ANS)
    return Question(prompt, [*_digits2(acc), EOS])


def gen_knowledge(rng: Pcg, domain: int) -> Question:
    subj = rng.next_below(N_SUBJ)
    rel = rng.next_below(RELS_PER_DOMAIN)
    ans = kb_answer(domain, subj, rel)
    # Three distinct distractors.
    distractors: list[int] = []
    while len(distractors) < 3:
        d = rng.next_below(N_ENT)
        if d != ans and d not in distractors:
            distractors.append(d)
    pos = rng.next_below(4)
    choices = distractors[:pos] + [ans] + distractors[pos:]
    prompt = [BOS, ENT0 + subj, REL0 + (domain - 1) * RELS_PER_DOMAIN + rel, SEP]
    prompt += [ENT0 + c for c in choices]
    prompt.append(ANS)
    return Question(prompt, [CH_A + pos, EOS])


def _apply_op(op: int, xs: list[int]) -> list[int]:
    if op == OP_SORT:
        return sorted(xs)
    if op == OP_REV:
        return xs[::-1]
    if op == OP_INC:
        return [(x + 1) % 10 for x in xs]
    if op == OP_DEC:
        return [(x - 1) % 10 for x in xs]
    if op == OP_MAX:
        return [max(xs)]
    if op == OP_MIN:
        return [min(xs)]
    raise ValueError(op)


def gen_transform(rng: Pcg) -> Question:
    n = 4 + rng.next_below(3)  # 4..6 digits
    xs = [rng.next_below(10) for _ in range(n)]
    op = TRANSFORM_OPS[rng.next_below(len(TRANSFORM_OPS))]
    out = _apply_op(op, xs)
    return Question(
        [BOS, op, *[DIG0 + x for x in xs], ANS],
        [*[DIG0 + x for x in out], EOS],
    )


def gen_transform_hard(rng: Pcg) -> Question:
    n = 4 + rng.next_below(3)
    xs = [rng.next_below(10) for _ in range(n)]
    # Second op must keep a list (not MAX/MIN) for the first slot.
    op1 = TRANSFORM_OPS[rng.next_below(4)]  # SORT REV INC DEC
    op2 = TRANSFORM_OPS[rng.next_below(len(TRANSFORM_OPS))]
    out = _apply_op(op2, _apply_op(op1, xs))
    return Question(
        [BOS, op1, op2, *[DIG0 + x for x in xs], ANS],
        [*[DIG0 + x for x in out], EOS],
    )


FAMILY_GENS = {
    "arith": lambda rng, dom: gen_arith(rng),
    "arith_chain": lambda rng, dom: gen_arith_chain(rng),
    "knowledge": gen_knowledge,
    "transform": lambda rng, dom: gen_transform(rng),
    "transform_hard": lambda rng, dom: gen_transform_hard(rng),
}

# Suite registry mirror (rust/src/eval/suites.rs is authoritative).
SUITES = [
    ("AIME 2024", "arith_chain", 0),
    ("MATH 500", "arith", 0),
    ("GPQA", "knowledge", 1),
    ("MBPP", "transform", 0),
    ("MBPP+", "transform", 0),
    ("LiveCodeBench", "transform_hard", 0),
    ("MMLU", "knowledge", 2),
    ("CMMLU", "knowledge", 3),
    ("C-Eval", "knowledge", 4),
]


def eval_question(suite_name: str, family: str, domain: int, qid: int) -> Question:
    """The exact question the Rust harness evaluates (suite stream)."""
    rng = Pcg(EVAL_SEED ^ fnv1a(suite_name)).derive(qid)
    return FAMILY_GENS[family](rng, domain)


def train_sample(mixture: list[tuple[str, int, float]], rng: Pcg) -> Question:
    """Draw one training sample from a ``(family, domain, weight)`` mix."""
    total = sum(w for _, _, w in mixture)
    r = rng.next_f64() * total
    acc = 0.0
    for family, domain, w in mixture:
        acc += w
        if r < acc:
            return FAMILY_GENS[family](rng, domain)
    family, domain, _ = mixture[-1]
    return FAMILY_GENS[family](rng, domain)


# Training mixtures per proxy checkpoint (DESIGN.md §2: the r1 proxy is
# reasoning-heavy, v3 balanced; v3-0324 is v3 trained longer).
MIXTURES = {
    "r1": [
        ("arith", 0, 0.22),
        ("arith_chain", 0, 0.22),
        ("knowledge", 1, 0.06),
        ("knowledge", 2, 0.10),
        ("knowledge", 3, 0.10),
        ("knowledge", 4, 0.10),
        ("transform", 0, 0.10),
        ("transform_hard", 0, 0.10),
    ],
    "v3": [
        ("arith", 0, 0.16),
        ("arith_chain", 0, 0.10),
        ("knowledge", 1, 0.08),
        ("knowledge", 2, 0.14),
        ("knowledge", 3, 0.14),
        ("knowledge", 4, 0.14),
        ("transform", 0, 0.14),
        ("transform_hard", 0, 0.10),
    ],
}
MIXTURES["v3_0324"] = MIXTURES["v3"]

MAX_PROMPT = 16
MAX_ANSWER = 8
SEQ_LEN = MAX_PROMPT + MAX_ANSWER  # 24


def pad_example(q: Question, seq_len: int = SEQ_LEN):
    """(tokens, loss_mask) for teacher-forced training.

    The loss mask is 1 on the answer tokens (positions predicting them).
    """
    toks = q.prompt + q.answer
    assert len(toks) <= seq_len, (len(toks), seq_len)
    mask = [0] * len(q.prompt) + [1] * len(q.answer)
    toks = toks + [PAD] * (seq_len - len(toks))
    mask = mask + [0] * (seq_len - len(mask))
    return toks, mask
