"""L2: the proxy models' forward pass in JAX.

Two architectures, matching `rust/src/model/config.rs`:

- **MLA + MoE** (`tiny-moe`): Multi-head Latent Attention with q/kv
  LoRA compression and RoPE on the decoupled key part, plus a
  DeepSeek-V3-style MoE FFN (shared expert + top-k routed experts,
  computed densely — at this scale gathering is slower than masking).
- **Dense GQA** (`tiny-dense`): standard Llama/Qwen-style block, the
  distill proxy.

Weights are a dict ``name → WeightTensor``; every linear goes through
[`linear`], which dispatches to the Pallas fused dequant-matmul
(`kernels.dequant_matmul.matmul_qT_nd`) when the tensor is packed, or a
plain jnp matmul for f32 (the training path). Tensor names match the
Rust census exactly.

Entry points:

- [`forward_train`]  — f32, full logits, teacher forcing (train.py).
- [`forward_prefill`] — logits at the last real position + KV cache.
- [`forward_decode`]  — one-token step updating the cache in place.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dequant_matmul

MODELS_DIR = Path(__file__).resolve().parents[2] / "configs" / "models"


@dataclass
class Config:
    """Mirror of rust ModelConfig (loaded from configs/models/*.json)."""

    name: str
    kind: str
    vocab_size: int
    hidden_size: int
    n_layers: int
    first_dense: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int
    intermediate_size: int
    moe_intermediate_size: int
    n_routed_experts: int
    n_shared_experts: int
    n_active_experts: int
    # RoPE frequency base; Qwen-style dense configs declare 1000000.
    # Optional with the classic default so pre-existing configs load.
    rope_base: float = 10000.0

    @classmethod
    def load(cls, name: str) -> "Config":
        with open(MODELS_DIR / f"{name}.json") as f:
            return cls(**json.load(f))

    def to_dict(self) -> dict:
        d = dict(self.__dict__)
        # Mirror rust ModelConfig::to_json: the default base stays
        # implicit so container headers written before the base became
        # configurable keep their exact bytes.
        if d.get("rope_base") == 10000.0:
            del d["rope_base"]
        return d

    def is_moe_layer(self, i: int) -> bool:
        return self.kind == "mla_moe" and i >= self.first_dense

    def kv_dim(self) -> int:
        """Per-token cache width."""
        if self.kind == "mla_moe":
            return self.kv_lora_rank + self.qk_rope_head_dim
        return 2 * self.n_kv_heads * self.head_dim


@dataclass
class WeightTensor:
    """One model weight: f32 array, or packed quantized bytes."""

    fmt: str  # "f32" or a quant format
    data: object  # f32 [..., n, k] or uint8 [rows, row_bytes]
    shape: tuple[int, ...]  # logical shape


def linear(x, w: WeightTensor):
    """``x @ W.T`` for a [n, k] weight (leading dims on x free)."""
    n, k = w.shape[-2], w.shape[-1]
    if w.fmt == "f32":
        return x @ w.data.T
    return dequant_matmul.matmul_qT_nd(x, w.data, fmt=w.fmt, n=n, k=k)


def expert_linear(x, w: WeightTensor, e: int):
    """Per-expert slice of an [E, n, k] stacked weight."""
    _, n, k = w.shape
    if w.fmt == "f32":
        return x @ w.data[e].T
    rows = w.data.reshape(w.shape[0], n, -1)
    return dequant_matmul.matmul_qT_nd(x, rows[e], fmt=w.fmt, n=n, k=k)


def stacked_linear(x, w: WeightTensor):
    """All experts of an [E, n, k] weight as one ``[..., E·n]`` matmul.

    The packed rows of every expert are already contiguous, so this is a
    pure reshape — one fused kernel call instead of E (the dominant
    XLA-graph-size / compile-time win; see DESIGN.md §Perf).
    """
    e, n, k = w.shape
    if w.fmt == "f32":
        return x @ w.data.reshape(e * n, k).T
    return dequant_matmul.matmul_qT_nd(
        x, w.data.reshape(e * n, -1), fmt=w.fmt, n=e * n, k=k
    )


def concat_k_linear(x, w: WeightTensor):
    """[E, n, k] expert weights fused along the *contraction* dim:
    ``y[.., n] = Σ_e x[.., e·k:(e+1)·k] @ W_e.T``.

    Because k-quant super-blocks never straddle a row (k % 256 == 0),
    the byte-transpose [E, n, kb] → [n, E·kb] reinterprets each output
    row as E consecutive runs of valid super-blocks — the whole MoE
    down-projection collapses into a single fused dequant-matmul.
    """
    e, n, k = w.shape
    if w.fmt == "f32":
        wt = w.data.transpose(1, 0, 2).reshape(n, e * k)
        return x @ wt.T
    kb = w.data.shape[-1] if w.data.ndim == 2 else None
    packed = w.data.reshape(e, n, -1).transpose(1, 0, 2).reshape(n, -1)
    del kb
    return dequant_matmul.matmul_qT_nd(x, packed, fmt=w.fmt, n=n, k=e * k)


def rms_norm(x, weight, eps=1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def rope(x, positions, base=10000.0):
    """Rotary embedding over the last dim. x: [..., T, D], positions [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


def _w(weights, name):
    return weights[f"{name}.weight"]


def _blk(weights, i, stem):
    return weights[f"blk.{i}.{stem}.weight"]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def mla_attention(cfg: Config, weights, i, x, positions, cache_kv, mask):
    """Multi-head Latent Attention.

    Args:
      x: [B, T, H] normed input.
      positions: [B, T] absolute positions of x.
      cache_kv: [B, C, kv_lora+rope] — compressed KV cache covering all
        positions (already containing this chunk; see callers).
      mask: [B, T, C] additive attention mask.
    Returns: [B, T, H] attention output.
    """
    b, t, _ = x.shape
    c = cache_kv.shape[1]
    h, nope, rp, vd = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = linear(x, _blk(weights, i, "attn_q_a"))
    q = rms_norm(q, _blk(weights, i, "attn_q_a_norm").data)
    q = linear(q, _blk(weights, i, "attn_q_b")).reshape(b, t, h, nope + rp)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(
        q_rope.transpose(0, 2, 1, 3), positions[:, None, :], base=cfg.rope_base
    ).transpose(0, 2, 1, 3)

    c_kv = cache_kv[..., : cfg.kv_lora_rank]  # [B, C, kv_lora] (normed)
    k_rope = cache_kv[..., cfg.kv_lora_rank :]  # [B, C, rope] (roped)

    kv = linear(c_kv, _blk(weights, i, "attn_kv_b")).reshape(b, c, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    # Scores: decoupled nope/rope parts (k_rope is shared across heads).
    scale = 1.0 / np.sqrt(nope + rp)
    s_nope = jnp.einsum("bthd,bchd->bhtc", q_nope, k_nope)
    s_rope = jnp.einsum("bthd,bcd->bhtc", q_rope, k_rope)
    scores = (s_nope + s_rope) * scale + mask[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhtc,bchd->bthd", probs, v).reshape(b, t, h * vd)
    return linear(out, _blk(weights, i, "attn_output"))


def mla_compress(cfg: Config, weights, i, x, positions):
    """Produce the cacheable compressed KV for a chunk: [B, T, kv_lora+rope]."""
    ckv = linear(x, _blk(weights, i, "attn_kv_a_mqa"))
    c_kv = rms_norm(ckv[..., : cfg.kv_lora_rank], _blk(weights, i, "attn_kv_a_norm").data)
    k_rope = rope(ckv[..., cfg.kv_lora_rank :], positions, base=cfg.rope_base)
    return jnp.concatenate([c_kv, k_rope], axis=-1)


def gqa_attention(cfg: Config, weights, i, x, positions, cache_k, cache_v, mask):
    """Standard GQA attention; caches hold full keys/values [B, C, KVH·D]."""
    b, t, _ = x.shape
    c = cache_k.shape[1]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = h // kvh

    q = linear(x, _blk(weights, i, "attn_q")).reshape(b, t, h, hd)
    q = rope(
        q.transpose(0, 2, 1, 3), positions[:, None, :], base=cfg.rope_base
    ).transpose(0, 2, 1, 3)
    k = cache_k.reshape(b, c, kvh, hd)
    v = cache_v.reshape(b, c, kvh, hd)
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)

    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bthd,bchd->bhtc", q, k) * scale + mask[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhtc,bchd->bthd", probs, v).reshape(b, t, h * hd)
    return linear(out, _blk(weights, i, "attn_output"))


def gqa_compress(cfg: Config, weights, i, x, positions):
    """Cacheable K (roped) and V for a chunk: each [B, T, KVH·D]."""
    b, t, _ = x.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = linear(x, _blk(weights, i, "attn_k")).reshape(b, t, kvh, hd)
    k = rope(
        k.transpose(0, 2, 1, 3), positions[:, None, :], base=cfg.rope_base
    ).transpose(0, 2, 1, 3)
    v = linear(x, _blk(weights, i, "attn_v"))
    return k.reshape(b, t, kvh * hd), v


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def dense_ffn(cfg: Config, weights, i, x):
    gate = linear(x, _blk(weights, i, "ffn_gate"))
    up = linear(x, _blk(weights, i, "ffn_up"))
    return linear(swiglu(gate, up), _blk(weights, i, "ffn_down"))


def moe_ffn(cfg: Config, weights, i, x):
    """DeepSeek-style MoE: shared expert + top-k routed (dense compute)."""
    e, k_act = cfg.n_routed_experts, cfg.n_active_experts
    router = _blk(weights, i, "ffn_gate_inp")  # f32 [E, H]
    logits = x @ router.data.T  # [B, T, E]
    # Top-k via iterated argmax: xla_extension 0.5.1's HLO text parser
    # predates the TopK op attribute jax's lax.top_k lowers to, and k is
    # tiny (2) anyway.
    masked = logits
    onehots = []
    topvs = []
    for _ in range(k_act):
        idx = jnp.argmax(masked, axis=-1)
        oh = jax.nn.one_hot(idx, e, dtype=x.dtype)  # [B, T, E]
        topvs.append(jnp.sum(masked * oh, axis=-1))
        masked = masked - oh * 1e9
        onehots.append(oh)
    topv = jnp.stack(topvs, axis=-1)  # [B, T, k]
    gates = jax.nn.softmax(topv, axis=-1)  # normalized over the top-k
    onehot = jnp.stack(onehots, axis=-2)  # [B, T, k, E]
    gate_full = jnp.einsum("btk,btke->bte", gates, onehot)

    # All-expert compute in three fused kernel calls: stacked gate/up
    # over the output dim, down fused over the contraction dim with the
    # routing gates folded into the activations (exact: the down
    # projection is linear, so g_e·down_e(h_e) = down_e(g_e·h_e)).
    m = cfg.moe_intermediate_size
    g = stacked_linear(x, _blk(weights, i, "ffn_gate_exps"))  # [B,T,E·M]
    u = stacked_linear(x, _blk(weights, i, "ffn_up_exps"))
    h = swiglu(g, u) * jnp.repeat(gate_full, m, axis=-1)
    out = concat_k_linear(h, _blk(weights, i, "ffn_down_exps"))

    sg = linear(x, _blk(weights, i, "ffn_gate_shexp"))
    su = linear(x, _blk(weights, i, "ffn_up_shexp"))
    out = out + linear(swiglu(sg, su), _blk(weights, i, "ffn_down_shexp"))
    return out


# ---------------------------------------------------------------------------
# Blocks and full passes
# ---------------------------------------------------------------------------


def block(cfg: Config, weights, i, x, positions, caches, mask):
    """One transformer block over chunk x given full caches."""
    h = rms_norm(x, _blk(weights, i, "attn_norm").data)
    if cfg.kind == "mla_moe":
        attn = mla_attention(cfg, weights, i, h, positions, caches[i], mask)
    else:
        ck, cv = caches[i]
        attn = gqa_attention(cfg, weights, i, h, positions, ck, cv, mask)
    x = x + attn
    h = rms_norm(x, _blk(weights, i, "ffn_norm").data)
    ffn = moe_ffn(cfg, weights, i, h) if cfg.is_moe_layer(i) else dense_ffn(cfg, weights, i, h)
    return x + ffn


def _compress_chunk(cfg, weights, i, x_normed, positions):
    if cfg.kind == "mla_moe":
        return mla_compress(cfg, weights, i, x_normed, positions)
    return gqa_compress(cfg, weights, i, x_normed, positions)


def embed(cfg: Config, weights, tokens):
    w = _w(weights, "token_embd")
    if w.fmt == "f32":
        table = w.data
    else:
        from .kernels.ref import dequant_rows

        table = dequant_rows(w.data, w.fmt, cfg.vocab_size, cfg.hidden_size)
    return jnp.take(table, tokens, axis=0)


def unembed(cfg: Config, weights, x):
    x = rms_norm(x, _w(weights, "output_norm").data)
    return linear(x, _w(weights, "output"))


def forward_train(cfg: Config, weights, tokens):
    """Teacher-forced full-sequence logits (f32 path). tokens: [B, T]."""
    b, t = tokens.shape
    x = embed(cfg, weights, tokens)
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    # Causal mask (PAD handling is done by the loss mask in train.py).
    causal = jnp.where(
        jnp.arange(t)[:, None] >= jnp.arange(t)[None, :], 0.0, -1e9
    ).astype(x.dtype)
    mask = jnp.broadcast_to(causal, (b, t, t))
    caches = {}
    for i in range(cfg.n_layers):
        h = rms_norm(x, _blk(weights, i, "attn_norm").data)
        caches[i] = _compress_chunk(cfg, weights, i, h, positions)
        x = block(cfg, weights, i, x, positions, caches, mask)
    return unembed(cfg, weights, x)


def forward_prefill(cfg: Config, weights, tokens, lengths, max_ctx: int):
    """Prefill: process padded prompts, return last-token logits + cache.

    Args:
      tokens: [B, T] right-padded prompts.
      lengths: [B] true prompt lengths (≥1).
      max_ctx: cache capacity C (≥ T).
    Returns:
      logits [B, V] at each sequence's last real token, cache.
      Cache: MLA → [L, B, C, kv_dim]; GQA → ([L,B,C,kd], [L,B,C,kd]).
    """
    b, t = tokens.shape
    x = embed(cfg, weights, tokens)
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    valid = positions < lengths[:, None]  # [B, T]
    causal = jnp.arange(t)[:, None] >= jnp.arange(t)[None, :]
    mask = jnp.where(causal[None] & valid[:, None, :], 0.0, -1e9).astype(x.dtype)

    if cfg.kind == "mla_moe":
        cache = jnp.zeros((cfg.n_layers, b, max_ctx, cfg.kv_dim()), jnp.float32)
        caches = {}
        for i in range(cfg.n_layers):
            h = rms_norm(x, _blk(weights, i, "attn_norm").data)
            ckv = _compress_chunk(cfg, weights, i, h, positions)  # [B,T,D]
            # Zero padded positions so they never leak via the cache.
            ckv = jnp.where(valid[..., None], ckv, 0.0)
            cache = cache.at[i, :, :t, :].set(ckv)
            caches[i] = ckv
            x = block(cfg, weights, i, x, positions, caches, mask)
        out_cache = cache
    else:
        kd = cfg.n_kv_heads * cfg.head_dim
        cache_k = jnp.zeros((cfg.n_layers, b, max_ctx, kd), jnp.float32)
        cache_v = jnp.zeros((cfg.n_layers, b, max_ctx, kd), jnp.float32)
        caches = {}
        for i in range(cfg.n_layers):
            h = rms_norm(x, _blk(weights, i, "attn_norm").data)
            k, v = _compress_chunk(cfg, weights, i, h, positions)
            k = jnp.where(valid[..., None], k, 0.0)
            v = jnp.where(valid[..., None], v, 0.0)
            cache_k = cache_k.at[i, :, :t, :].set(k)
            cache_v = cache_v.at[i, :, :t, :].set(v)
            caches[i] = (k, v)
            x = block(cfg, weights, i, x, positions, caches, mask)
        out_cache = (cache_k, cache_v)

    logits = unembed(cfg, weights, x)  # [B, T, V]
    last = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]
    return last, out_cache


def forward_decode(cfg: Config, weights, token, pos, cache):
    """One decode step.

    Args:
      token: [B] current token ids.
      pos: [B] positions to write (== current sequence length).
      cache: as returned by forward_prefill.
    Returns: logits [B, V], updated cache.
    """
    b = token.shape[0]
    if cfg.kind == "mla_moe":
        max_ctx = cache.shape[2]
    else:
        max_ctx = cache[0].shape[2]
    x = embed(cfg, weights, token[:, None])  # [B, 1, H]
    positions = pos[:, None]  # [B, 1]
    # Attend to everything written so far plus the current token.
    ctx_pos = jnp.arange(max_ctx)[None, :]  # [1, C]
    attend = ctx_pos <= pos[:, None]  # [B, C]
    mask = jnp.where(attend, 0.0, -1e9).astype(x.dtype)[:, None, :]  # [B,1,C]

    bidx = jnp.arange(b)
    if cfg.kind == "mla_moe":
        caches = {}
        for i in range(cfg.n_layers):
            h = rms_norm(x, _blk(weights, i, "attn_norm").data)
            ckv = _compress_chunk(cfg, weights, i, h, positions)  # [B,1,D]
            cache = cache.at[i, bidx, pos, :].set(ckv[:, 0, :])
            caches[i] = cache[i]
            x = block(cfg, weights, i, x, positions, caches, mask)
        out_cache = cache
    else:
        cache_k, cache_v = cache
        caches = {}
        for i in range(cfg.n_layers):
            h = rms_norm(x, _blk(weights, i, "attn_norm").data)
            k, v = _compress_chunk(cfg, weights, i, h, positions)
            cache_k = cache_k.at[i, bidx, pos, :].set(k[:, 0, :])
            cache_v = cache_v.at[i, bidx, pos, :].set(v[:, 0, :])
            caches[i] = (cache_k[i], cache_v[i])
            x = block(cfg, weights, i, x, positions, caches, mask)
        out_cache = (cache_k, cache_v)

    logits = unembed(cfg, weights, x)[:, 0, :]
    return logits, out_cache


# ---------------------------------------------------------------------------
# Initialization (training path)
# ---------------------------------------------------------------------------


def census(cfg: Config):
    """(name, class, layer, shape) for every weight — mirrors Rust census."""
    out = [("token_embd.weight", "token_embd", None, (cfg.vocab_size, cfg.hidden_size))]
    for i in range(cfg.n_layers):
        blk_ = lambda stem, cls, shape: out.append(
            (f"blk.{i}.{stem}.weight", cls, i, shape)
        )
        blk_("attn_norm", "norm", (cfg.hidden_size,))
        if cfg.kind == "mla_moe":
            qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            blk_("attn_q_a", "attn_q_a", (cfg.q_lora_rank, cfg.hidden_size))
            blk_("attn_q_a_norm", "norm", (cfg.q_lora_rank,))
            blk_("attn_q_b", "attn_q_b", (cfg.n_heads * qk, cfg.q_lora_rank))
            blk_(
                "attn_kv_a_mqa",
                "attn_kv_a_mqa",
                (cfg.kv_lora_rank + cfg.qk_rope_head_dim, cfg.hidden_size),
            )
            blk_("attn_kv_a_norm", "norm", (cfg.kv_lora_rank,))
            blk_(
                "attn_kv_b",
                "attn_kv_b",
                (cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), cfg.kv_lora_rank),
            )
            blk_(
                "attn_output",
                "attn_output",
                (cfg.hidden_size, cfg.n_heads * cfg.v_head_dim),
            )
        else:
            blk_("attn_q", "attn_q", (cfg.n_heads * cfg.head_dim, cfg.hidden_size))
            blk_("attn_k", "attn_k", (cfg.n_kv_heads * cfg.head_dim, cfg.hidden_size))
            blk_("attn_v", "attn_v", (cfg.n_kv_heads * cfg.head_dim, cfg.hidden_size))
            blk_(
                "attn_output",
                "attn_output",
                (cfg.hidden_size, cfg.n_heads * cfg.head_dim),
            )
        blk_("ffn_norm", "norm", (cfg.hidden_size,))
        if cfg.is_moe_layer(i):
            e, m, h = cfg.n_routed_experts, cfg.moe_intermediate_size, cfg.hidden_size
            sh = cfg.n_shared_experts * m
            blk_("ffn_gate_inp", "ffn_gate_inp", (e, h))
            blk_("ffn_gate_exps", "ffn_gate_exps", (e, m, h))
            blk_("ffn_up_exps", "ffn_up_exps", (e, m, h))
            blk_("ffn_down_exps", "ffn_down_exps", (e, h, m))
            blk_("ffn_gate_shexp", "ffn_gate_shexp", (sh, h))
            blk_("ffn_up_shexp", "ffn_up_shexp", (sh, h))
            blk_("ffn_down_shexp", "ffn_down_shexp", (h, sh))
        else:
            blk_("ffn_gate", "ffn_gate", (cfg.intermediate_size, cfg.hidden_size))
            blk_("ffn_up", "ffn_up", (cfg.intermediate_size, cfg.hidden_size))
            blk_("ffn_down", "ffn_down", (cfg.hidden_size, cfg.intermediate_size))
    out.append(("output_norm.weight", "norm", None, (cfg.hidden_size,)))
    out.append(("output.weight", "output", None, (cfg.vocab_size, cfg.hidden_size)))
    return out


def init_weights(cfg: Config, seed: int) -> dict:
    """f32 initialization (truncated-normal-ish, scaled by fan-in)."""
    rng = np.random.default_rng(seed)
    weights = {}
    for name, cls, _layer, shape in census(cfg):
        if cls == "norm":
            data = np.ones(shape, np.float32)
        else:
            fan_in = shape[-1]
            data = rng.normal(0.0, fan_in**-0.5, shape).astype(np.float32)
        weights[name] = WeightTensor("f32", jnp.asarray(data), tuple(shape))
    return weights
