"""Python reader/writer for the `.dsq` container (mirror of
`rust/src/container/`). train.py writes f32 checkpoints with this; the
AOT pipeline and tests read both f32 and Rust-quantized containers."""

from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from . import quants

MAGIC = b"DSQ1"
DATA_ALIGN = 4096
TENSOR_ALIGN = 64


@dataclass
class Entry:
    name: str
    cls: str
    layer: int | None
    shape: tuple[int, ...]
    fmt: str
    offset: int
    nbytes: int

    @property
    def n_elems(self) -> int:
        return int(np.prod(self.shape))


@dataclass
class Container:
    model: dict
    scheme: str
    meta: dict
    entries: list[Entry]
    data: bytes

    @classmethod
    def open(cls, path: str | Path) -> "Container":
        raw = Path(path).read_bytes()
        if raw[:4] != MAGIC:
            raise ValueError(f"{path}: not a DSQ1 container")
        (hlen,) = struct.unpack("<I", raw[4:8])
        header = json.loads(raw[8 : 8 + hlen].decode())
        if header["version"] != 1:
            raise ValueError(f"unsupported version {header['version']}")
        data_start = -(-(8 + hlen) // DATA_ALIGN) * DATA_ALIGN
        entries = []
        for t in header["tensors"]:
            e = Entry(
                name=t["name"],
                cls=t["class"],
                layer=t["layer"],
                shape=tuple(t["shape"]),
                fmt=t["format"],
                offset=t["offset"],
                nbytes=t["nbytes"],
            )
            expect = quants.row_bytes(e.fmt, e.n_elems)
            if expect != e.nbytes:
                raise ValueError(f"{e.name}: nbytes {e.nbytes} != {expect}")
            entries.append(e)
        return cls(
            model=header["model"],
            scheme=header["scheme"],
            meta=header.get("meta", {}),
            entries=entries,
            data=raw[data_start:],
        )

    def entry(self, name: str) -> Entry:
        for e in self.entries:
            if e.name == name:
                return e
        raise KeyError(name)

    def raw(self, e: Entry) -> np.ndarray:
        return np.frombuffer(self.data, np.uint8, e.nbytes, e.offset)

    def packed(self, e: Entry) -> np.ndarray:
        """Packed bytes reshaped to [rows, row_bytes] (kernel layout).

        Expert tensors [E, N, K] flatten to [E·N, row_bytes].
        """
        rows = e.n_elems // e.shape[-1]
        return self.raw(e).reshape(rows, -1).copy()

    def dequantize(self, e: Entry) -> np.ndarray:
        return quants.dequantize(e.fmt, self.raw(e), e.n_elems).reshape(e.shape)


@dataclass
class Writer:
    model: dict
    scheme: str
    meta: dict = field(default_factory=dict)
    entries: list[Entry] = field(default_factory=list)
    chunks: list[bytes] = field(default_factory=list)
    size: int = 0

    def add(self, name: str, cls: str, layer, array: np.ndarray, fmt: str = "f32"):
        """Add a tensor. For f32 the array is stored verbatim."""
        if fmt != "f32":
            raise ValueError("python writer only emits f32 checkpoints")
        arr = np.ascontiguousarray(array, dtype=np.float32)
        payload = arr.tobytes()
        aligned = -(-self.size // TENSOR_ALIGN) * TENSOR_ALIGN
        if aligned > self.size:
            self.chunks.append(b"\0" * (aligned - self.size))
            self.size = aligned
        self.entries.append(
            Entry(name, cls, layer, tuple(arr.shape), fmt, self.size, len(payload))
        )
        self.chunks.append(payload)
        self.size += len(payload)

    def to_bytes(self) -> bytes:
        tensors = [
            {
                "name": e.name,
                "class": e.cls,
                "layer": e.layer,
                "shape": list(e.shape),
                "format": e.fmt,
                "offset": e.offset,
                "nbytes": e.nbytes,
            }
            for e in self.entries
        ]
        header = json.dumps(
            {
                "version": 1,
                "model": self.model,
                "scheme": self.scheme,
                "meta": self.meta,
                "tensors": tensors,
            },
            separators=(",", ":"),
        ).encode()
        data_start = -(-(8 + len(header)) // DATA_ALIGN) * DATA_ALIGN
        out = bytearray()
        out += MAGIC
        out += struct.pack("<I", len(header))
        out += header
        out += b"\0" * (data_start - len(out))
        for c in self.chunks:
            out += c
        return bytes(out)

    def write(self, path: str | Path):
        Path(path).write_bytes(self.to_bytes())
