"""k-quant block *dequantization*, mirroring `rust/src/quant/` bit-for-bit.

The Rust side owns quantization (packing); this module only unpacks, and
is written generically over an array module ``xp`` (numpy or jax.numpy)
so the same code serves:

- the pure-numpy reference path (container loading, oracles), and
- the Pallas/JAX kernels (L1), which call these functions on tiles.

Layouts (identical byte sizes to llama.cpp; flat element order — see the
Rust module docs for the authoritative description):

==========  =====  ===========  =========================================
format      block  bytes/block  structure
==========  =====  ===========  =========================================
``q8_0``       32           34  f16 d | 32×i8
``q6_k``      256          210  ql128 | qh64 | 16×i8 sc | f16 d
``q5_k``      256          176  d | dmin | sc+m 12B | qh32 | qs128
``q4_k``      256          144  d | dmin | sc+m 12B | qs128
``q3_k``      256          110  sc 12B | hmask32 | qs64 | f16 d
``q2_k``      256           84  16×(sc|m<<4) | qs64 | f16 d | f16 dmin
==========  =====  ===========  =========================================

Cross-language correctness is pinned by ``tests/test_quants.py`` against
test vectors emitted by ``dsq testvec``.
"""

from __future__ import annotations

import numpy as np

BLOCK_BYTES = {
    "f32": 4,
    "f16": 2,
    "q8_0": 34,
    "q6_k": 210,
    "q5_k": 176,
    "q4_k": 144,
    "q3_k": 110,
    "q2_k": 84,
}
BLOCK_WEIGHTS = {
    "f32": 1,
    "f16": 2 // 2,
    "q8_0": 32,
    "q6_k": 256,
    "q5_k": 256,
    "q4_k": 256,
    "q3_k": 256,
    "q2_k": 256,
}
FORMATS = list(BLOCK_BYTES)


def bits_per_weight(fmt: str) -> float:
    return BLOCK_BYTES[fmt] * 8.0 / BLOCK_WEIGHTS[fmt]


def row_bytes(fmt: str, n: int) -> int:
    bw = BLOCK_WEIGHTS[fmt]
    if n % bw:
        raise ValueError(f"{fmt}: {n} weights not a multiple of block {bw}")
    return n // bw * BLOCK_BYTES[fmt]


def _f16(xp, lo, hi):
    """Decode IEEE half from two uint8 arrays (little endian)."""
    bits = lo.astype(xp.uint16) | (hi.astype(xp.uint16) << 8)
    if xp is np:
        return bits.view(np.float16).astype(np.float32)
    import jax

    return jax.lax.bitcast_convert_type(bits, xp.float16).astype(xp.float32)


def _nibbles(xp, b):
    """[nb, K] uint8 → [nb, 2K] codes: element 2i = low nibble of b[i]."""
    lo = b & 0x0F
    hi = b >> 4
    return xp.stack([lo, hi], axis=-1).reshape(b.shape[0], -1)


def _crumbs(xp, b):
    """[nb, K] uint8 → [nb, 4K] 2-bit codes, bits 2·(i&3)."""
    parts = [(b >> (2 * k)) & 0x03 for k in range(4)]
    return xp.stack(parts, axis=-1).reshape(b.shape[0], -1)


def _bits(xp, b):
    """[nb, K] uint8 → [nb, 8K] single bits, bit (i&7)."""
    parts = [(b >> k) & 0x01 for k in range(8)]
    return xp.stack(parts, axis=-1).reshape(b.shape[0], -1)


def _rep(xp, v, sub):
    """Repeat per-sub-block values across their `sub` elements."""
    return xp.repeat(v, sub, axis=-1)


def unpack_q8_0(xp, blocks):
    """[nb, 34] uint8 → [nb, 32] f32."""
    d = _f16(xp, blocks[:, 0], blocks[:, 1])[:, None]
    q = blocks[:, 2:34].astype(xp.int8).astype(xp.float32)
    return d * q


def unpack_q6_k(xp, blocks):
    """[nb, 210] uint8 → [nb, 256] f32."""
    lo = _nibbles(xp, blocks[:, 0:128])
    hi = _crumbs(xp, blocks[:, 128:192])
    c = (lo | (hi << 4)).astype(xp.int32)
    sc = blocks[:, 192:208].astype(xp.int8).astype(xp.float32)
    d = _f16(xp, blocks[:, 208], blocks[:, 209])[:, None]
    return d * _rep(xp, sc, 16) * (c - 32).astype(xp.float32)


def _scale_min_6(xp, b12):
    """Unpack 8 six-bit scales + 8 six-bit mins from [nb, 12] bytes."""
    sc = b12[:, 0:8] & 0x3F
    m_lo = b12[:, 0:8] >> 6  # 2 bits
    hi_nib = _nibbles(xp, b12[:, 8:12])  # [nb, 8] 4-bit values
    m = m_lo | (hi_nib << 2)
    return sc.astype(xp.float32), m.astype(xp.float32)


def unpack_q4_k(xp, blocks):
    """[nb, 144] uint8 → [nb, 256] f32."""
    d = _f16(xp, blocks[:, 0], blocks[:, 1])[:, None]
    dmin = _f16(xp, blocks[:, 2], blocks[:, 3])[:, None]
    sc, m = _scale_min_6(xp, blocks[:, 4:16])
    c = _nibbles(xp, blocks[:, 16:144]).astype(xp.float32)
    return d * _rep(xp, sc, 32) * c - dmin * _rep(xp, m, 32)


def unpack_q5_k(xp, blocks):
    """[nb, 176] uint8 → [nb, 256] f32."""
    d = _f16(xp, blocks[:, 0], blocks[:, 1])[:, None]
    dmin = _f16(xp, blocks[:, 2], blocks[:, 3])[:, None]
    sc, m = _scale_min_6(xp, blocks[:, 4:16])
    hi = _bits(xp, blocks[:, 16:48])
    lo = _nibbles(xp, blocks[:, 48:176])
    c = (lo | (hi << 4)).astype(xp.float32)
    return d * _rep(xp, sc, 32) * c - dmin * _rep(xp, m, 32)


def _scales_6x16(xp, b12):
    """Unpack 16 six-bit scale codes from [nb, 12] bytes (q3_k)."""
    lo = _nibbles(xp, b12[:, 0:8])  # [nb, 16]: j<8 low nibble, j>=8 high
    # Flat nibble order is [b0.lo, b0.hi, b1.lo, ...] = [sc0, sc8, sc1, sc9, ...]
    # Reorder to [sc0..sc7, sc8..sc15].
    lo = lo.reshape(b12.shape[0], 8, 2).transpose(0, 2, 1).reshape(b12.shape[0], 16)
    hi = _crumbs(xp, b12[:, 8:12])  # [nb, 16]: byte 8+k bits 2t → sc[4t+k]
    # Flat crumb order is [b8.t0, b8.t1, b8.t2, b8.t3, b9.t0, ...] where
    # b(8+k) crumb t is sc[4t+k]; reorder accordingly.
    hi = hi.reshape(b12.shape[0], 4, 4).transpose(0, 2, 1).reshape(b12.shape[0], 16)
    return (lo | (hi << 4)).astype(xp.float32)


def unpack_q3_k(xp, blocks):
    """[nb, 110] uint8 → [nb, 256] f32."""
    sc = _scales_6x16(xp, blocks[:, 0:12]) - 32.0
    hi = _bits(xp, blocks[:, 12:44])
    lo = _crumbs(xp, blocks[:, 44:108])
    c = (lo | (hi << 2)).astype(xp.float32)
    d = _f16(xp, blocks[:, 108], blocks[:, 109])[:, None]
    return d * _rep(xp, sc, 16) * (c - 4.0)


def unpack_q2_k(xp, blocks):
    """[nb, 84] uint8 → [nb, 256] f32."""
    sc = (blocks[:, 0:16] & 0x0F).astype(xp.float32)
    m = (blocks[:, 0:16] >> 4).astype(xp.float32)
    c = _crumbs(xp, blocks[:, 16:80]).astype(xp.float32)
    d = _f16(xp, blocks[:, 80], blocks[:, 81])[:, None]
    dmin = _f16(xp, blocks[:, 82], blocks[:, 83])[:, None]
    return d * _rep(xp, sc, 16) * c - dmin * _rep(xp, m, 16)


UNPACKERS = {
    "q8_0": unpack_q8_0,
    "q6_k": unpack_q6_k,
    "q5_k": unpack_q5_k,
    "q4_k": unpack_q4_k,
    "q3_k": unpack_q3_k,
    "q2_k": unpack_q2_k,
}


def dequantize(fmt: str, raw: np.ndarray, n: int, xp=np):
    """Dequantize `n` weights from packed bytes `raw` (1-D uint8)."""
    if fmt == "f32":
        if xp is np:
            return raw.view(np.float32)[:n].copy()
        raise ValueError("f32 passthrough is numpy-only at container level")
    if fmt == "f16":
        if xp is np:
            return raw.view(np.float16)[:n].astype(np.float32)
        raise ValueError("f16 passthrough is numpy-only at container level")
    bb, bw = BLOCK_BYTES[fmt], BLOCK_WEIGHTS[fmt]
    nb = n // bw
    blocks = raw.reshape(nb, bb)
    return UNPACKERS[fmt](xp, blocks).reshape(n)
