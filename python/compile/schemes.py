"""Python mirror of the Rust scheme engine (`rust/src/scheme/`).

Reads the same ``configs/schemes/*.json`` files; `assign` reproduces
`Scheme::assign` exactly (including llama.cpp's `use_more_bits` mix and
the DQ3_K_M dynamic rule) so the AOT-compiled graphs expect precisely
the per-tensor formats the Rust quantizer produces. Pinned by
``tests/test_schemes.py`` golden assignments.
"""

from __future__ import annotations

import json
from pathlib import Path

from . import quants

SCHEMES_DIR = Path(__file__).resolve().parents[2] / "configs" / "schemes"

SCHEME_NAMES = [
    "f32",
    "q8_0",
    "q4_k_m",
    "q4_k",
    "q3_k_m",
    "q3_k",
    "dq3_k_m",
    "q2_k_l",
    "ud_q2_k_xl",
]


def load_scheme(name: str) -> dict:
    with open(SCHEMES_DIR / f"{name}.json") as f:
        s = json.load(f)
    assert s["name"] == name
    return s


def use_more_bits(i_layer: int, n_layer: int) -> bool:
    return (
        i_layer < n_layer // 8
        or i_layer >= 7 * n_layer // 8
        or (i_layer - n_layer // 8) % 3 == 2
    )


def assign(scheme: dict, cls: str, layer, row_len: int, n_params: int, cfg) -> str:
    """Format for a tensor of module class `cls` at `layer`.

    `cfg` needs `.n_layers` and `.first_dense` (duck-typed; the model
    config objects in model.py provide them).
    """
    if cls in ("norm", "ffn_gate_inp"):
        return "f32"
    rule = next((r for r in scheme["rules"] if r["module"] == cls), None)
    if rule is None:
        fmt = scheme["default"]
    elif "format" in rule:
        fmt = rule["format"]
    elif "more_bits" in rule:
        li = layer or 0
        fmt = rule["more_bits"]["high" if use_more_bits(li, cfg.n_layers) else "low"]
    elif "dynamic" in rule:
        dy = rule["dynamic"]
        li = layer or 0
        moe_idx = max(0, li - cfg.first_dense)
        if moe_idx < dy["first_moe"]:
            fmt = dy["first_format"]
        elif dy["period"] > 0 and li % dy["period"] == 0:
            fmt = dy["period_format"]
        else:
            fmt = dy["default"]
    else:
        raise ValueError(f"bad rule for {cls}")
    bw = quants.BLOCK_WEIGHTS[fmt]
    if row_len % bw or n_params % bw:
        return "f16"
    return fmt
