"""Pallas fused dequantize-matmul — the paper's compute hot-spot on TPU.

## Hardware adaptation (DESIGN.md §Hardware-Adaptation)

llama.cpp's k-quant kernels unpack per-warp on CUDA. The TPU rethink
tiles at the **VMEM boundary** instead: the grid walks output-row tiles
of the quantized weight matrix; each step BlockSpec-streams one
``[TILE_N, K_bytes]`` slab of *packed* super-blocks HBM→VMEM (3.4–8.5
bits/weight — the whole point of the paper is that this is the memory
traffic you pay), unpacks it with VPU integer ops, and feeds the f32
``[TILE_N, K]`` tile plus the ``[B, K]`` activation tile to the MXU.

VMEM budget per grid step (TILE_N=128, K=512, q4_k):
  packed slab 128·288 B = 36 KiB, unpacked tile 128·512·4 = 256 KiB,
  activations 16·512·4 = 32 KiB, accumulator 16·128·4 = 8 KiB
  → well under the ~16 MiB VMEM of a modern TPU core.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpec structure is what a real TPU
lowering would tile on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quants

# Output-row tile. Weight matrices in this project have N ∈ {256, ...};
# the tile divides every N used by the models.
TILE_N = 256


def _kernel(x_ref, wq_ref, o_ref, *, fmt: str, k: int):
    """One grid step: o[B, TILE_N] = x[B, K] @ dequant(wq[TILE_N, :]).T."""
    x = x_ref[...]
    wq = wq_ref[...]
    tile_n = wq.shape[0]
    bw = quants.BLOCK_WEIGHTS[fmt]
    bb = quants.BLOCK_BYTES[fmt]
    blocks = wq.reshape(tile_n * (k // bw), bb)
    w = quants.UNPACKERS[fmt](jnp, blocks).reshape(tile_n, k)
    o_ref[...] = jax.lax.dot_general(
        x,
        w,
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("fmt", "n", "k"))
def matmul_qT(x, wq, *, fmt: str, n: int, k: int):
    """Fused ``x @ dequant(wq).T`` as a Pallas kernel.

    Args:
      x: f32 ``[b, k]`` activations (2-D; callers flatten leading dims).
      wq: uint8 ``[n, k_bytes]`` packed weights (row-major blocks).
      fmt: quant format name; ``"f32"``/``"f16"`` take a fast path with
        no unpacking.
      n, k: logical weight shape.

    Returns:
      f32 ``[b, n]``.
    """
    if fmt in ("f32", "f16"):
        # No bit-twiddling needed; let XLA fuse the cast into the matmul.
        from . import ref

        w = ref.dequant_rows(wq, fmt, n, k)
        return x @ w.T

    b = x.shape[0]
    k_bytes = k // quants.BLOCK_WEIGHTS[fmt] * quants.BLOCK_BYTES[fmt]
    assert wq.shape == (n, k_bytes), (wq.shape, (n, k_bytes))
    # Largest divisor of n within the VMEM tile budget (output dims like
    # kv_lora+rope = 288 are not multiples of 128).
    tile = next(d for d in range(min(TILE_N, n), 0, -1) if n % d == 0)

    return pl.pallas_call(
        functools.partial(_kernel, fmt=fmt, k=k),
        grid=(n // tile,),
        in_specs=[
            # Activations are resident for every grid step.
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            # One packed row-tile of the weight matrix per step: this is
            # the HBM→VMEM stream the paper's memory claims are about.
            pl.BlockSpec((tile, k_bytes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, wq)


def matmul_qT_nd(x, wq, *, fmt: str, n: int, k: int):
    """As `matmul_qT` but accepting arbitrary leading dims on `x`."""
    lead = x.shape[:-1]
    flat = x.reshape(-1, k)
    out = matmul_qT(flat, wq, fmt=fmt, n=n, k=k)
    return out.reshape(*lead, n)
