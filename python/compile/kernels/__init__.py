"""L1 kernels: Pallas fused dequantize-matmul, with a pure-jnp oracle.

`dequant_matmul.matmul_qT` is the hot-spot primitive every quantized
linear layer in the L2 model lowers to.
"""
