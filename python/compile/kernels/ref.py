"""Pure-jnp correctness oracle for the Pallas kernels.

`dequant_rows` reconstructs a quantized weight matrix exactly (same
block math as `rust/src/quant`); `matmul_qT_ref` is the reference for
the fused kernel: ``x @ dequant(Wq).T``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import quants


def dequant_rows(wq, fmt: str, n: int, k: int):
    """Dequantize a packed weight matrix.

    Args:
      wq: uint8 array ``[n, k_bytes]`` — each row is row-major packed
        blocks of the row's `k` weights.
      fmt: quant format name (``"q4_k"`` ...) or ``"f32"``/``"f16"``.
      n, k: logical matrix shape.

    Returns:
      f32 array ``[n, k]``.
    """
    if fmt == "f32":
        return jnp.asarray(wq).view(jnp.float32).reshape(n, k)
    if fmt == "f16":
        return jnp.asarray(wq).view(jnp.float16).reshape(n, k).astype(jnp.float32)
    bb = quants.BLOCK_BYTES[fmt]
    bw = quants.BLOCK_WEIGHTS[fmt]
    blocks = jnp.asarray(wq).reshape(n * (k // bw), bb)
    w = quants.UNPACKERS[fmt](jnp, blocks)
    return w.reshape(n, k)


def matmul_qT_ref(x, wq, fmt: str, n: int, k: int):
    """Reference for the fused kernel: ``x @ dequant(wq).T``.

    Args:
      x: f32 ``[..., k]`` activations.
      wq: packed weights ``[n, k_bytes]``.
    Returns:
      f32 ``[..., n]``.
    """
    w = dequant_rows(wq, fmt, n, k)
    return x @ w.T
