"""Container format tests incl. cross-language interop."""

from pathlib import Path

import numpy as np
import pytest

from compile import container, model

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def test_writer_reader_roundtrip(tmp_path):
    cfg = model.Config.load("tiny-dense")
    w = container.Writer(model=cfg.to_dict(), scheme="f32", meta={"k": 1})
    rng = np.random.default_rng(0)
    arrays = {}
    for name, cls, layer, shape in model.census(cfg):
        arr = rng.normal(size=shape).astype(np.float32)
        arrays[name] = arr
        w.add(name, cls, layer, arr)
    p = tmp_path / "t.dsq"
    w.write(p)
    c = container.Container.open(p)
    assert c.model["name"] == "tiny-dense"
    assert c.meta == {"k": 1}
    for e in c.entries:
        np.testing.assert_array_equal(c.dequantize(e), arrays[e.name])


def test_alignment(tmp_path):
    cfg = model.Config.load("tiny-dense")
    w = container.Writer(model=cfg.to_dict(), scheme="f32")
    w.add("a.weight", "norm", None, np.ones(3, np.float32))
    w.add("b.weight", "norm", None, np.ones(5, np.float32))
    data = w.to_bytes()
    c = container.Container.from_bytes if hasattr(container.Container, "from_bytes") else None
    p = tmp_path / "x.dsq"
    (p).write_bytes(data)
    cc = container.Container.open(p)
    assert cc.entry("b.weight").offset % container.TENSOR_ALIGN == 0


@pytest.mark.skipif(
    not (ARTIFACTS / "ckpt" / "smoke.dq3_k_m.dsq").exists(),
    reason="rust-quantized smoke checkpoint not built",
)
def test_read_rust_quantized_container():
    """The Rust `dsq quantize` output parses and dequantizes."""
    c = container.Container.open(ARTIFACTS / "ckpt" / "smoke.dq3_k_m.dsq")
    assert c.scheme == "dq3_k_m"
    e = c.entry("blk.1.ffn_down_exps.weight")
    assert e.fmt == "q6_k"  # first MoE layer under the dynamic rule
    vals = c.dequantize(e)
    assert vals.shape == (8, 256, 256)
    assert np.isfinite(vals).all()
    # Reconstruction must correlate with the f32 source.
    src = container.Container.open(ARTIFACTS / "ckpt" / "smoke.f32.dsq")
    ref = src.dequantize(src.entry(e.name))
    rel = np.sqrt(np.mean((vals - ref) ** 2) / np.mean(ref**2))
    assert rel < 0.05, rel
