"""L2 model tests: shapes, prefill/decode/train consistency, quantized
weight path."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import container, model, quants, schemes, tasks


@pytest.fixture(scope="module")
def moe():
    cfg = model.Config.load("tiny-moe")
    return cfg, model.init_weights(cfg, 0)


@pytest.fixture(scope="module")
def dense():
    cfg = model.Config.load("tiny-dense")
    return cfg, model.init_weights(cfg, 1)


def test_census_matches_rust_expectations(moe):
    cfg, _ = moe
    names = [n for n, _, _, _ in model.census(cfg)]
    assert "blk.1.ffn_down_exps.weight" in names
    assert "blk.0.ffn_down.weight" in names  # layer 0 dense
    assert len(names) == len(set(names))


@pytest.mark.parametrize("fixture", ["moe", "dense"])
def test_prefill_matches_teacher_forcing(fixture, request):
    cfg, w = request.getfixturevalue(fixture)
    rng = np.random.default_rng(3)
    b, t = 2, 10
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, t), dtype=np.int32))
    lengths = jnp.asarray([7, 10], dtype=np.int32)
    last, cache = model.forward_prefill(cfg, w, toks, lengths, max_ctx=16)
    full = model.forward_train(cfg, w, toks)
    np.testing.assert_allclose(
        np.asarray(last[0]), np.asarray(full[0, 6]), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(last[1]), np.asarray(full[1, 9]), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("fixture", ["moe", "dense"])
def test_decode_continues_prefill(fixture, request):
    """Decoding token t+1 must equal teacher-forcing at position t+1."""
    cfg, w = request.getfixturevalue(fixture)
    rng = np.random.default_rng(4)
    b, t = 2, 6
    toks = rng.integers(1, cfg.vocab_size, (b, t + 1), dtype=np.int32)
    lengths = jnp.asarray([t, t], dtype=np.int32)
    last, cache = model.forward_prefill(cfg, w, jnp.asarray(toks[:, :t]), lengths, max_ctx=12)
    logits, _ = model.forward_decode(
        cfg, w, jnp.asarray(toks[:, t]), jnp.asarray([t, t]), cache
    )
    full = model.forward_train(cfg, w, jnp.asarray(toks))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]), rtol=1e-3, atol=1e-4)


def test_quantized_weights_run(moe):
    """Random packed weights through the full fwd (format plumbing)."""
    cfg, _ = moe
    scheme = schemes.load_scheme("dq3_k_m")
    rng = np.random.default_rng(5)
    weights = {}
    for name, cls, layer, shape in model.census(cfg):
        row_len = shape[-1]
        n_params = int(np.prod(shape))
        fmt = schemes.assign(scheme, cls, layer, row_len, n_params, cfg)
        if fmt == "f32":
            data = jnp.asarray(rng.normal(0, 0.02, shape).astype(np.float32))
        else:
            from tests.test_kernels import random_packed

            rows = n_params // row_len
            data = jnp.asarray(random_packed(fmt, rows, row_len, int(rng.integers(1 << 30))))
        weights[name] = model.WeightTensor(fmt, data, tuple(shape))
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 8), dtype=np.int32))
    last, cache = model.forward_prefill(cfg, weights, toks, jnp.asarray([8, 8]), max_ctx=12)
    assert last.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(last)).all()
