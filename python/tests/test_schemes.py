"""Scheme-mirror tests: python assignment == rust scheme engine."""

import pytest

from compile import model, schemes


@pytest.fixture(scope="module")
def moe():
    return model.Config.load("tiny-moe")


def test_all_schemes_load():
    for name in schemes.SCHEME_NAMES:
        s = schemes.load_scheme(name)
        assert s["name"] == name


def test_dq3_dynamic_assignment(moe):
    s = schemes.load_scheme("dq3_k_m")
    # tiny-moe: first_dense=1, layers 1..5 are MoE. first_moe=2 → layers
    # 1,2 get q6_k; layer 5 (period 5) → q4_k; layers 3,4 → q3_k.
    expect = {1: "q6_k", 2: "q6_k", 3: "q3_k", 4: "q3_k", 5: "q4_k"}
    for layer, fmt in expect.items():
        got = schemes.assign(s, "ffn_down_exps", layer, 256, 8 * 256 * 256, moe)
        assert got == fmt, (layer, got)


def test_norms_stay_f32(moe):
    for name in schemes.SCHEME_NAMES:
        s = schemes.load_scheme(name)
        assert schemes.assign(s, "norm", 0, 256, 256, moe) == "f32"
        assert schemes.assign(s, "ffn_gate_inp", 1, 256, 2048, moe) == "f32"


def test_ragged_rows_fall_back_to_f16(moe):
    s = schemes.load_scheme("q4_k_m")
    assert schemes.assign(s, "attn_output", 0, 100, 10000, moe) == "f16"


def test_use_more_bits_split():
    # 61-layer model: 27 of the 58 MoE layers are high-precision.
    n = sum(schemes.use_more_bits(i, 61) for i in range(3, 61))
    assert n == 27


def test_q4_k_m_table7_rows(moe):
    s = schemes.load_scheme("q4_k_m")
    assert schemes.assign(s, "output", None, 256, 512 * 256, moe) == "q6_k"
    assert schemes.assign(s, "token_embd", None, 256, 512 * 256, moe) == "q4_k"
    assert schemes.assign(s, "ffn_gate_exps", 3, 256, 8 * 256 * 256, moe) == "q4_k"
