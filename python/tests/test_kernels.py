"""L1 kernel correctness: Pallas fused dequant-matmul vs the pure-jnp
oracle, swept over formats and shapes with hypothesis."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quants
from compile.kernels import dequant_matmul, ref

# Packing requires the Rust quantizer; for kernel tests we only need
# *valid* packed bytes. Random bytes decode for every field EXCEPT the
# f16 block scales, where exponent-31 patterns are Inf/NaN — so we mask
# the scale high bytes down to finite range.

F16_HI_BYTES = {  # (block_bytes, [offsets of f16 high bytes])
    "q8_0": (34, [1]),
    "q6_k": (210, [209]),
    "q5_k": (176, [1, 3]),
    "q4_k": (144, [1, 3]),
    "q3_k": (110, [109]),
    "q2_k": (84, [81, 83]),
}


def random_packed(fmt: str, n: int, k: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    kb = quants.row_bytes(fmt, k)
    raw = rng.integers(0, 256, (n, kb), dtype=np.uint8)
    bb, his = F16_HI_BYTES[fmt]
    blocks = raw.reshape(-1, bb)
    for off in his:
        blocks[:, off] &= 0x3F  # exponent <= 15, finite f16
    return blocks.reshape(n, kb)


QUANT_FORMATS = ["q8_0", "q6_k", "q5_k", "q4_k", "q3_k", "q2_k"]


@pytest.mark.parametrize("fmt", QUANT_FORMATS)
def test_kernel_matches_ref(fmt):
    n, k, b = 256, 256, 4
    wq = random_packed(fmt, n, k, 1)
    x = np.random.default_rng(2).normal(size=(b, k)).astype(np.float32)
    got = dequant_matmul.matmul_qT(jnp.asarray(x), jnp.asarray(wq), fmt=fmt, n=n, k=k)
    want = np.asarray(ref.matmul_qT_ref(jnp.asarray(x), jnp.asarray(wq), fmt, n, k))
    tol = 1e-5 * max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=tol)


@settings(max_examples=20, deadline=None)
@given(
    fmt=st.sampled_from(QUANT_FORMATS),
    n_blocks=st.integers(1, 3),
    k_blocks=st.integers(1, 2),
    b=st.integers(1, 5),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_property(fmt, n_blocks, k_blocks, b, seed):
    """Hypothesis sweep: shapes × formats × data."""
    bw = quants.BLOCK_WEIGHTS[fmt]
    n = 128 * n_blocks
    k = max(bw, 256) * k_blocks
    wq = random_packed(fmt, n, k, seed)
    x = np.random.default_rng(seed ^ 1).normal(size=(b, k)).astype(np.float32)
    got = dequant_matmul.matmul_qT(jnp.asarray(x), jnp.asarray(wq), fmt=fmt, n=n, k=k)
    want = np.asarray(ref.matmul_qT_ref(jnp.asarray(x), jnp.asarray(wq), fmt, n, k))
    tol = 1e-5 * max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=tol)


def test_f32_passthrough():
    n, k = 64, 32
    w = np.random.default_rng(0).normal(size=(n, k)).astype(np.float32)
    x = np.random.default_rng(1).normal(size=(2, k)).astype(np.float32)
    got = dequant_matmul.matmul_qT(jnp.asarray(x), jnp.asarray(w), fmt="f32", n=n, k=k)
    np.testing.assert_allclose(np.asarray(got), x @ w.T, rtol=1e-5, atol=1e-5)


def test_nd_wrapper():
    fmt, n, k = "q4_k", 128, 256
    wq = random_packed(fmt, n, k, 3)
    x = np.random.default_rng(4).normal(size=(2, 3, k)).astype(np.float32)
    got = dequant_matmul.matmul_qT_nd(jnp.asarray(x), jnp.asarray(wq), fmt=fmt, n=n, k=k)
    assert got.shape == (2, 3, n)
    flat = dequant_matmul.matmul_qT(jnp.asarray(x.reshape(6, k)), jnp.asarray(wq), fmt=fmt, n=n, k=k)
    np.testing.assert_allclose(np.asarray(got).reshape(6, n), np.asarray(flat), rtol=1e-6)


def test_odd_output_dim_tiling():
    """n=288 (kv_lora+rope) forces the non-128 tile path."""
    fmt, n, k = "q6_k", 288, 256
    wq = random_packed(fmt, n, k, 5)
    x = np.random.default_rng(6).normal(size=(2, k)).astype(np.float32)
    got = dequant_matmul.matmul_qT(jnp.asarray(x), jnp.asarray(wq), fmt=fmt, n=n, k=k)
    want = np.asarray(ref.matmul_qT_ref(jnp.asarray(x), jnp.asarray(wq), fmt, n, k))
    tol = 1e-5 * max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=tol)
