"""Task generator + RNG mirror tests (cross-language contract)."""

import pytest

from compile import tasks


def test_rng_golden():
    """Golden sequence pinned against rust/src/util/rng.rs (seed 42)."""
    r = tasks.Pcg(42)
    got = [r.next_u64() for _ in range(4)]
    # Recompute via the spec: splitmix64.
    def splitmix(state):
        M = (1 << 64) - 1
        state = (state + 0x9E3779B97F4A7C15) & M
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M
        return state, z ^ (z >> 31)
    s = (42 + 0x9E3779B97F4A7C15) & ((1 << 64) - 1)
    expect = []
    for _ in range(4):
        s, v = splitmix(s)
        expect.append(v)
    assert got == expect


def test_next_below_bounds():
    r = tasks.Pcg(1)
    assert all(r.next_below(7) < 7 for _ in range(10000))


def test_arith_answers():
    rng = tasks.Pcg(99)
    for _ in range(200):
        q = tasks.gen_arith(rng)
        a = (q.prompt[1] - tasks.DIG0) * 10 + (q.prompt[2] - tasks.DIG0)
        b = (q.prompt[4] - tasks.DIG0) * 10 + (q.prompt[5] - tasks.DIG0)
        c = (q.answer[0] - tasks.DIG0) * 10 + (q.answer[1] - tasks.DIG0)
        expect = (a + b) % 100 if q.prompt[3] == tasks.OP_ADD else (a - b) % 100
        assert c == expect
        assert q.answer[-1] == tasks.EOS


def test_knowledge_answer_position():
    rng = tasks.Pcg(5)
    for _ in range(100):
        q = tasks.gen_knowledge(rng, 3)
        pos = q.answer[0] - tasks.CH_A
        subj = q.prompt[1] - tasks.ENT0
        rel = q.prompt[2] - tasks.REL0 - 2 * tasks.RELS_PER_DOMAIN
        assert q.prompt[4 + pos] - tasks.ENT0 == tasks.kb_answer(3, subj, rel)


def test_prompts_fit_shapes():
    for name, family, domain in tasks.SUITES:
        for qid in range(100):
            q = tasks.eval_question(name, family, domain, qid)
            assert len(q.prompt) <= tasks.MAX_PROMPT, (name, q)
            assert len(q.answer) <= tasks.MAX_ANSWER
            assert all(0 <= t < tasks.VOCAB for t in q.prompt + q.answer)


def test_eval_stream_deterministic():
    a = tasks.eval_question("MATH 500", "arith", 0, 17)
    b = tasks.eval_question("MATH 500", "arith", 0, 17)
    assert a == b


def test_pad_example():
    rng = tasks.Pcg(3)
    q = tasks.gen_transform(rng)
    toks, mask = tasks.pad_example(q)
    assert len(toks) == tasks.SEQ_LEN == len(mask)
    assert sum(mask) == len(q.answer)


def test_transform_ops():
    rng = tasks.Pcg(8)
    for _ in range(100):
        q = tasks.gen_transform_hard(rng)
        assert q.prompt[1] in tasks.TRANSFORM_OPS[:4]
        assert q.prompt[2] in tasks.TRANSFORM_OPS


def test_mixtures_normalized():
    for name, mix in tasks.MIXTURES.items():
        total = sum(w for _, _, w in mix)
        assert abs(total - 1.0) < 1e-9, name
