"""Codec mirror tests: python unpack == rust pack→dequant, bit for bit.

Requires `dsq testvec --out artifacts/testvectors` (run by
`make artifacts`); skipped when the vectors are absent.
"""

import json
from pathlib import Path

import numpy as np
import pytest

from compile import quants

VEC_DIR = Path(__file__).resolve().parents[2] / "artifacts" / "testvectors"

pytestmark = pytest.mark.skipif(
    not (VEC_DIR / "index.json").exists(),
    reason="test vectors not built (run `make artifacts`)",
)


def _cases():
    if not (VEC_DIR / "index.json").exists():
        return []
    return json.loads((VEC_DIR / "index.json").read_text())


@pytest.mark.parametrize("case", _cases(), ids=lambda c: c["format"])
def test_python_dequant_matches_rust(case):
    fmt, n = case["format"], case["n"]
    packed = np.fromfile(VEC_DIR / f"{fmt}.packed.bin", np.uint8)
    rust_deq = np.fromfile(VEC_DIR / f"{fmt}.deq.f32", np.float32)
    py_deq = quants.dequantize(fmt, packed, n)
    np.testing.assert_array_equal(py_deq, rust_deq, err_msg=fmt)


@pytest.mark.parametrize("case", _cases(), ids=lambda c: c["format"])
def test_reconstruction_error_bounded(case):
    fmt, n = case["format"], case["n"]
    if fmt == "f16":
        return
    src = np.fromfile(VEC_DIR / f"{fmt}.src.f32", np.float32)
    packed = np.fromfile(VEC_DIR / f"{fmt}.packed.bin", np.uint8)
    deq = quants.dequantize(fmt, packed, n)
    rel = np.sqrt(np.mean((src - deq) ** 2) / np.mean(src**2))
    bound = {"q8_0": 0.01, "q6_k": 0.02, "q5_k": 0.05, "q4_k": 0.09,
             "q3_k": 0.17, "q2_k": 0.35}[fmt]
    assert rel < bound, (fmt, rel)


def test_row_bytes():
    assert quants.row_bytes("q4_k", 512) == 288
    with pytest.raises(ValueError):
        quants.row_bytes("q4_k", 100)
