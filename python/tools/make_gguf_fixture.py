#!/usr/bin/env python3
"""Generate the committed tiny-dense GGUF fixture (and its import golden)
for the Rust `container::gguf` test suite — so CI needs no network.

The fixture is a *foreign-style* GGUF v3 file: `qwen2.*` metadata only
(no `dsq.*` keys), tensors written in reversed census order, payloads in
llama.cpp bit placement. It holds the same synthetic tiny-dense weights
as `synthetic_f32_container(tiny_dense, 0x601D)` quantized under
`q4_k_m`, produced by the bit-exact mirror in `bless_goldens.py` — so
the Rust importer must reconstruct a container byte-identical to its own
`dsq quantize` output, pinned here by `import.tiny_dense.q4_k_m.fnv64`.

Payload transcoding (our dense bit placement → llama.cpp's interleaved
planes) is an independent Python port of the Rust `to_llama` functions;
this script self-checks every payload two ways before writing anything:

  1. round-trip: from_llama(to_llama(p)) == p for every tensor;
  2. semantics: integer codes + scales extracted from the llama-placement
     bytes via loops transcribed from llama.cpp's `dequantize_row_q4_K` /
     `dequantize_row_q6_K` / `get_scale_min_k4` must equal the codes +
     scales extracted from the native bytes via the native layout.

Usage:  python3 python/tools/make_gguf_fixture.py [--check-only]
"""

from __future__ import annotations

import struct
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))

from bless_goldens import (  # noqa: E402
    GOLDEN_DIR,
    TINY_DENSE,
    Pcg,
    build_container,
    fnv64,
    quantize_census,
    tiny_dense_census,
)

U8 = np.uint8
SEED = 0x601D
SCHEME = "q4_k_m"
ALIGN = 32
GGML_TYPE = {"f32": 0, "f16": 1, "q8_0": 8, "q2_k": 10, "q3_k": 11,
             "q4_k": 12, "q5_k": 13, "q6_k": 14}
BLOCK_BYTES = {"q2_k": 84, "q3_k": 110, "q4_k": 144, "q5_k": 176, "q6_k": 210}

FIXTURE = GOLDEN_DIR / "tiny_dense.q4_k_m.gguf"
IMPORT_GOLDEN = GOLDEN_DIR / "import.tiny_dense.q4_k_m.fnv64"


# ---------------------------------------------------------------------------
# Bit-plane moves (vectorized over blocks; element index i is the weight
# position, identical on both sides — only (byte, shift) placement moves)
# ---------------------------------------------------------------------------


def _move(blocks, src, dst, mask, out):
    """out[:, dst_byte] |= ((blocks[:, src_byte] >> src_shift) & mask) << dst_shift."""
    for (sb, ss), (db, ds) in zip(src, dst):
        out[:, db] |= ((blocks[:, sb] >> ss) & mask) << ds


def _plane2(base):
    """llama 2-bit plane: i = 128g + 32j + l → byte base+32g+l, shift 2j."""
    return [(base + 32 * (i >> 7) + (i & 31), 2 * ((i >> 5) & 3)) for i in range(256)]


def _nib_llama(base):
    """llama nibble plane: i = 64g + r → byte base+32g+(r%32), shift 4·(r≥32)."""
    return [(base + 32 * (i >> 6) + ((i & 63) & 31), 4 * ((i & 63) >= 32)) for i in range(256)]


def _dense(base, bits):
    per = 8 // bits
    return [(base + i // per, bits * (i % per)) for i in range(256)]


def _scale_min_native_unpack(b):
    sc = np.zeros((len(b), 8), U8)
    mn = np.zeros((len(b), 8), U8)
    for j in range(8):
        sc[:, j] = b[:, j] & 0x3F
        mn[:, j] = (b[:, j] >> 6) | (((b[:, 8 + j // 2] >> (4 * (j & 1))) & 0x0F) << 2)
    return sc, mn


def _scale_min_llama_pack(sc, mn, out):
    for j in range(4):
        out[:, j] = (sc[:, j] & 63) | ((sc[:, j + 4] >> 4) << 6)
        out[:, j + 4] = (mn[:, j] & 63) | ((mn[:, j + 4] >> 4) << 6)
        out[:, j + 8] = (sc[:, j + 4] & 0x0F) | ((mn[:, j + 4] & 0x0F) << 4)


def _scale_min_llama_unpack(b):
    sc = np.zeros((len(b), 8), U8)
    mn = np.zeros((len(b), 8), U8)
    for j in range(8):
        if j < 4:
            sc[:, j] = b[:, j] & 63
            mn[:, j] = b[:, j + 4] & 63
        else:
            sc[:, j] = (b[:, j + 4] & 0x0F) | ((b[:, j - 4] >> 6) << 4)
            mn[:, j] = (b[:, j + 4] >> 4) | ((b[:, j] >> 6) << 4)
    return sc, mn


def _scale_min_native_pack(sc, mn, out):
    for j in range(8):
        out[:, j] = (sc[:, j] & 0x3F) | ((mn[:, j] & 0x03) << 6)
    for k in range(4):
        out[:, 8 + k] = (mn[:, 2 * k] >> 2) | ((mn[:, 2 * k + 1] >> 2) << 4)


def transcode(fmt: str, payload: bytes, to_llama: bool) -> bytes:
    """Move payload bits between native and llama.cpp placement (pure
    bijective permutation; the inverse of itself with flipped arg)."""
    if fmt not in BLOCK_BYTES:
        return payload  # f32 / f16 / q8_0 are byte-identical
    bb = BLOCK_BYTES[fmt]
    blk = np.frombuffer(payload, U8).reshape(-1, bb)
    out = np.zeros_like(blk)
    if fmt == "q2_k":
        out[:, :16] = blk[:, :16]
        out[:, 80:84] = blk[:, 80:84]
        nat, lla = _dense(16, 2), _plane2(16)
        _move(blk, *((nat, lla) if to_llama else (lla, nat)), 3, out)
    elif fmt == "q3_k":
        # field order: llama hmask|qs|scales|d, ours scales|hmask|qs|d;
        # the 12 scale bytes are byte-identical.
        if to_llama:
            out[:, 96:108] = blk[:, :12]
        else:
            out[:, :12] = blk[:, 96:108]
        out[:, 108:110] = blk[:, 108:110]
        nat_h = _dense(12, 1)
        lla_h = [(i & 31, i >> 5) for i in range(256)]
        _move(blk, *((nat_h, lla_h) if to_llama else (lla_h, nat_h)), 1, out)
        nat_q, lla_q = _dense(44, 2), _plane2(32)
        _move(blk, *((nat_q, lla_q) if to_llama else (lla_q, nat_q)), 3, out)
    elif fmt in ("q4_k", "q5_k"):
        out[:, :4] = blk[:, :4]
        if to_llama:
            sc, mn = _scale_min_native_unpack(blk[:, 4:16])
            _scale_min_llama_pack(sc, mn, out[:, 4:16])
        else:
            sc, mn = _scale_min_llama_unpack(blk[:, 4:16])
            _scale_min_native_pack(sc, mn, out[:, 4:16])
        qs_off = 16 if fmt == "q4_k" else 48
        if fmt == "q5_k":
            nat_h = _dense(16, 1)
            lla_h = [(16 + ((i & 63) & 31), 2 * (i >> 6) + ((i & 63) >= 32))
                     for i in range(256)]
            _move(blk, *((nat_h, lla_h) if to_llama else (lla_h, nat_h)), 1, out)
        nat_q, lla_q = _dense(qs_off, 4), _nib_llama(qs_off)
        _move(blk, *((nat_q, lla_q) if to_llama else (lla_q, nat_q)), 0x0F, out)
    elif fmt == "q6_k":
        out[:, 192:210] = blk[:, 192:210]
        nat_l = _dense(0, 4)
        lla_l = [(64 * (i >> 7) + 32 * (((i >> 5) & 3) & 1) + (i & 31),
                  4 * (((i >> 5) & 3) >> 1)) for i in range(256)]
        _move(blk, *((nat_l, lla_l) if to_llama else (lla_l, nat_l)), 0x0F, out)
        nat_h, lla_h = _dense(128, 2), _plane2(128)
        _move(blk, *((nat_h, lla_h) if to_llama else (lla_h, nat_h)), 3, out)
    return out.tobytes()


# ---------------------------------------------------------------------------
# Independent semantic checks, transcribed from llama.cpp's dequant loops
# ---------------------------------------------------------------------------


def _check_q4k_semantics(native: bytes, llama: bytes):
    nb = np.frombuffer(native, U8).reshape(-1, 144)
    lb = np.frombuffer(llama, U8).reshape(-1, 144)
    assert np.array_equal(nb[:, :4], lb[:, :4])  # d, dmin
    sc_n, mn_n = _scale_min_native_unpack(nb[:, 4:16])
    sc_l, mn_l = _scale_min_llama_unpack(lb[:, 4:16])  # = get_scale_min_k4
    assert np.array_equal(sc_n, sc_l) and np.array_equal(mn_n, mn_l)
    codes_n = np.zeros((len(nb), 256), U8)
    for i in range(256):
        codes_n[:, i] = (nb[:, 16 + i // 2] >> (4 * (i % 2))) & 0x0F
    # dequantize_row_q4_K: per 64-group, 32 low nibbles then 32 high.
    codes_l = np.zeros_like(codes_n)
    for g in range(4):
        for l in range(32):
            codes_l[:, 64 * g + l] = lb[:, 16 + 32 * g + l] & 0x0F
            codes_l[:, 64 * g + 32 + l] = lb[:, 16 + 32 * g + l] >> 4
    assert np.array_equal(codes_n, codes_l), "q4_k code permutation broken"


def _check_q6k_semantics(native: bytes, llama: bytes):
    nb = np.frombuffer(native, U8).reshape(-1, 210)
    lb = np.frombuffer(llama, U8).reshape(-1, 210)
    assert np.array_equal(nb[:, 192:210], lb[:, 192:210])  # sc[16], d
    codes_n = np.zeros((len(nb), 256), U8)
    for i in range(256):
        lo = (nb[:, i // 2] >> (4 * (i % 2))) & 0x0F
        hi = (nb[:, 128 + i // 4] >> (2 * (i % 4))) & 3
        codes_n[:, i] = lo | (hi << 4)
    # dequantize_row_q6_K: q1..q4 per 128-group.
    codes_l = np.zeros_like(codes_n)
    for n in range(2):
        for l in range(32):
            ql, qh = lb[:, 64 * n + l], lb[:, 128 + 32 * n + l]
            ql32 = lb[:, 64 * n + 32 + l]
            codes_l[:, 128 * n + l] = (ql & 0x0F) | (((qh >> 0) & 3) << 4)
            codes_l[:, 128 * n + 32 + l] = (ql32 & 0x0F) | (((qh >> 2) & 3) << 4)
            codes_l[:, 128 * n + 64 + l] = (ql >> 4) | (((qh >> 4) & 3) << 4)
            codes_l[:, 128 * n + 96 + l] = (ql32 >> 4) | (((qh >> 6) & 3) << 4)
    assert np.array_equal(codes_n, codes_l), "q6_k code permutation broken"


# ---------------------------------------------------------------------------
# GGUF v3 writer (foreign-style: qwen2 metadata, no dsq keys)
# ---------------------------------------------------------------------------


def _gstr(s: str) -> bytes:
    return struct.pack("<Q", len(s)) + s.encode()


def _kv_u32(key: str, v: int) -> bytes:
    return _gstr(key) + struct.pack("<II", 4, v)


def _kv_f32(key: str, v: float) -> bytes:
    return _gstr(key) + struct.pack("<If", 6, v)


def _kv_str(key: str, v: str) -> bytes:
    return _gstr(key) + struct.pack("<I", 8) + _gstr(v)


def build_gguf(quantized: list[dict]) -> bytes:
    c = TINY_DENSE
    kvs = [
        _kv_str("general.architecture", "qwen2"),
        _kv_str("general.name", c["name"]),
        _kv_u32("qwen2.block_count", c["n_layers"]),
        _kv_u32("qwen2.embedding_length", c["hidden_size"]),
        _kv_u32("qwen2.feed_forward_length", c["intermediate_size"]),
        _kv_u32("qwen2.attention.head_count", c["n_heads"]),
        _kv_u32("qwen2.attention.head_count_kv", c["n_kv_heads"]),
        _kv_u32("qwen2.attention.key_length", c["head_dim"]),
        _kv_f32("qwen2.rope.freq_base", float(c["rope_base"])),
    ]
    # Reversed census order in the file: the importer must reassemble in
    # census order regardless of on-disk order.
    entries = list(reversed(quantized))
    infos, data = [], bytearray()
    for q in entries:
        payload = transcode(q["format"], bytes(q["payload"]), to_llama=True)
        off = -(-len(data) // ALIGN) * ALIGN
        data.extend(b"\0" * (off - len(data)))
        data.extend(payload)
        dims = list(reversed(q["shape"]))  # GGUF stores ne[0] (row) first
        infos.append(
            _gstr(q["name"])
            + struct.pack("<I", len(dims))
            + b"".join(struct.pack("<Q", d) for d in dims)
            + struct.pack("<IQ", GGML_TYPE[q["format"]], off)
        )
    head = bytearray()
    head += b"GGUF" + struct.pack("<IQQ", 3, len(entries), len(kvs))
    for kv in kvs:
        head += kv
    for info in infos:
        head += info
    head += b"\0" * (-(-len(head) // ALIGN) * ALIGN - len(head))
    return bytes(head) + bytes(data)


def main():
    check_only = "--check-only" in sys.argv
    census = tiny_dense_census()
    rng = Pcg(SEED)
    values = {}
    for name, _cls, _layer, shape in census:
        values[name] = rng.normals(int(np.prod(shape)), 0.05)
    print(f"· synthetic tiny-dense weights, seed {SEED:#x} "
          f"({sum(v.size for v in values.values())} f32)")

    quantized = quantize_census(SCHEME, values, census=census, model=TINY_DENSE)
    fmts = sorted({q["format"] for q in quantized})
    print(f"· quantized under {SCHEME}: formats {fmts}")

    for q in quantized:
        native = bytes(q["payload"])
        llama = transcode(q["format"], native, to_llama=True)
        back = transcode(q["format"], llama, to_llama=False)
        assert back == native, f"{q['name']}: transcode round-trip broken"
        if q["format"] == "q4_k":
            _check_q4k_semantics(native, llama)
        elif q["format"] == "q6_k":
            _check_q6k_semantics(native, llama)
    print("· transcode self-checks passed (round-trip + llama.cpp-loop semantics)")

    gguf_blob = build_gguf(quantized)
    container_blob = build_container(SCHEME, quantized, model=TINY_DENSE)
    golden_line = f"{fnv64(container_blob):016x} {len(container_blob)}\n"
    outputs = {FIXTURE: gguf_blob, IMPORT_GOLDEN: golden_line.encode()}

    if check_only:
        stale = [p.name for p, blob in outputs.items()
                 if not p.exists() or p.read_bytes() != blob]
        if stale:
            print(f"STALE fixtures: {stale} — rerun without --check-only")
            sys.exit(1)
        print("· fixtures up to date")
        return
    for p, blob in outputs.items():
        p.write_bytes(blob)
        print(f"· wrote {p.relative_to(GOLDEN_DIR.parents[1])} ({len(blob)} bytes)")
    print(f"· expected import container: fnv64 {golden_line.split()[0]}, "
          f"{len(container_blob)} bytes")


if __name__ == "__main__":
    main()
