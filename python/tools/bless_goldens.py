#!/usr/bin/env python3
"""Offline golden-fixture blessing for `rust/tests/golden/`.

`tests/golden_vectors.rs` blesses its fixtures on first run, which needs
a Rust toolchain. This tool produces the *identical* bytes from Python —
a bit-exact mirror of the Rust encode pipeline — so the fixtures can be
blessed (and the CI byte-drift gate armed) from a toolchain-less host.

The authoritative path remains `cargo test --release --test
golden_vectors`: if this mirror and the Rust encoder ever disagree, the
golden test fails and the fixtures must be re-blessed from Rust (delete
+ rerun). The mirror reproduces, operation for operation in IEEE f32:

- `util::rng::Pcg` (splitmix64; next_f32 / next_f64 / next_normal),
- `util::f16` (round-to-nearest-even f32→f16, exact f16→f32),
- the lane-chunked scale searches `make_qx_quants` / `make_qkx_quants`
  (element `i` → lane `i % 8`, sequential per-lane f32 sums, `hsum`
  fold, `qround` ties-away clamp — see `rust/src/quant/scalar.rs`),
- every block packer (`q2_k` … `q8_0`, raw `f32`/`f16`),
- `synthetic_f32_container` + `Scheme::plan` + the `.dsq` writer
  (compact JSON, 64-byte tensor / 4096-byte data alignment),
- the native **forward pass** (`rust/src/runtime/forward.rs`) for both
  model kinds: the deterministic f32 transcendentals of `util::math`
  (exp / ln / sin / cos / softmax / silu), the lane-ordered matvecs and
  RMSNorm sums, MLA attention with the compressed-latent KV cache and
  top-k expert routing (tiny-moe) **and** dense grouped-query attention
  with the conventional per-head K/V cache (tiny-dense, Qwen-style
  θ=1000000 RoPE base) — producing the `forward.*.fnv64` and
  `forward.tiny_dense.*.fnv64` golden-logits checksums for the DQ3_K_M
  and Q4_K_M containers (each cross-checked against an independent
  float64 numpy forward before anything is written).

Every fixture is additionally cross-checked against the *independent*
mirrors that already live in `python/compile/` (quants.py dequantizer,
schemes.py assignment, container.py reader), and the vectorized search
is verified sub-block-by-sub-block against a second, scalar
transcription of the Rust code before anything is written.

Usage:  python3 python/tools/bless_goldens.py [--check-only]
"""

from __future__ import annotations

import json
import math
import struct
import sys
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "python"))

from compile import quants as pyquants  # noqa: E402
from compile import schemes as pyschemes  # noqa: E402

GOLDEN_DIR = REPO / "rust" / "tests" / "golden"

F32 = np.float32
MASK64 = (1 << 64) - 1
LANES = 8

# ---------------------------------------------------------------------------
# util::rng::Pcg — exact splitmix64 mirror (see rust/src/util/rng.rs)
# ---------------------------------------------------------------------------


class Pcg:
    GAMMA = 0x9E3779B97F4A7C15

    def __init__(self, seed: int):
        self.state = (seed + self.GAMMA) & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + self.GAMMA) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def next_f32(self) -> np.float32:
        # (u >> 40) as f32 / (1 << 24) as f32 — both conversions exact.
        return F32(F32(self.next_u64() >> 40) / F32(16777216.0))

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) / 9007199254740992.0

    def next_normal(self) -> np.float32:
        # ((-2·ln u1).sqrt() · cos(2π·u2)) as f32, all in f64 libm —
        # CPython's math.log/cos call the same libm as Rust's f64 ops.
        u1 = max(self.next_f64(), 1e-12)
        u2 = self.next_f64()
        return F32(math.sqrt(-2.0 * math.log(u1)) * math.cos((2.0 * math.pi) * u2))

    def normals(self, n: int, scale: float) -> np.ndarray:
        s = F32(scale)
        return np.array([F32(self.next_normal() * s) for _ in range(n)], dtype=F32)


# ---------------------------------------------------------------------------
# util::f16 — exact integer-algorithm port (round to nearest even)
# ---------------------------------------------------------------------------


def f32_to_f16_bits(v: np.ndarray) -> np.ndarray:
    """Vectorized port of `f32_to_f16_bits` (rust/src/util/f16.rs)."""
    x = np.ascontiguousarray(v, dtype=F32).view(np.uint32)
    sign = ((x >> 16) & 0x8000).astype(np.uint32)
    exp = ((x >> 23) & 0xFF).astype(np.int64)
    man = (x & 0x007FFFFF).astype(np.uint32)
    out = np.zeros(x.shape, dtype=np.uint32)

    unbiased = exp - 127
    # Normal range.
    norm = (exp != 255) & (unbiased >= -14) & (unbiased <= 15)
    h = sign | (((unbiased + 15).astype(np.uint32) << 10) & 0xFFFF) | (man >> 13)
    dropped = man & 0x1FFF
    h = h + (((dropped > 0x1000) | ((dropped == 0x1000) & ((h & 1) == 1)))).astype(
        np.uint32
    )
    out = np.where(norm, h, out)
    # Denormal halves.
    den = (exp != 255) & (unbiased >= -24) & (unbiased < -14)
    shift = np.where(den, (-14 - unbiased), 0).astype(np.uint32)
    full = man | 0x00800000
    half_man = full >> (13 + shift)
    dmask = (np.uint64(1) << (13 + shift).astype(np.uint64)) - np.uint64(1)
    ddropped = full.astype(np.uint64) & dmask
    halfway = np.uint64(1) << (12 + shift).astype(np.uint64)
    hd = half_man + (
        (ddropped > halfway) | ((ddropped == halfway) & ((half_man & 1) == 1))
    ).astype(np.uint32)
    out = np.where(den, sign | hd, out)
    # Underflow to signed zero / overflow to inf / inf-nan inputs.
    out = np.where((exp != 255) & (unbiased < -24), sign, out)
    out = np.where((exp != 255) & (unbiased > 15), sign | 0x7C00, out)
    out = np.where((exp == 255) & (man == 0), sign | 0x7C00, out)
    out = np.where(
        (exp == 255) & (man != 0), sign | 0x7E00 | ((man >> 13) & 0x01FF), out
    )
    return (out & 0xFFFF).astype(np.uint16)


def f16_bits_to_f32(bits: np.ndarray) -> np.ndarray:
    # IEEE widening is exact; numpy's view+astype implements it exactly.
    return np.asarray(bits, dtype=np.uint16).view(np.float16).astype(F32)


def round_f16(v: np.ndarray) -> np.ndarray:
    """get_f16(put_f16(v)) — the stored-scale roundtrip."""
    return f16_bits_to_f32(f32_to_f16_bits(v))


# ---------------------------------------------------------------------------
# quant::simd / quant::scalar — qround, lane sums, scale searches
# ---------------------------------------------------------------------------


def qround(v: np.ndarray, lo: float, hi: float) -> np.ndarray:
    """`v.round().max(lo).min(hi)` — f32 round, ties away from zero.
    (Rust f32::max/min ignore NaN operands, so a NaN input yields `lo`.)"""
    v64 = np.asarray(v, dtype=np.float64)
    r = np.where(v64 >= 0.0, np.floor(v64 + 0.5), np.ceil(v64 - 0.5)).astype(F32)
    r = np.where(np.isnan(v64), F32(lo), r)
    return np.minimum(np.maximum(r, F32(lo)), F32(hi))


def nearest_int(v: np.ndarray) -> np.ndarray:
    """`x.round() as i32` — ties away from zero, with Rust's saturating
    float→int cast semantics (±inf clamp to i32 bounds, NaN → 0)."""
    v64 = np.asarray(v, dtype=np.float64)
    r = np.where(v64 >= 0.0, np.floor(v64 + 0.5), np.ceil(v64 - 0.5))
    r = np.where(np.isnan(r), 0.0, np.clip(r, -2147483648.0, 2147483647.0))
    return r.astype(np.int64)


def _lane_hsum(acc):
    """simd::hsum — sequential fold over the 8 lanes."""
    s = acc[..., 0]
    for lane in range(1, LANES):
        s = s + acc[..., lane]
    return s


def _lane_sums(terms):
    """Accumulate [S, n] f32 term arrays in the canonical lane order:
    element i → lane i%8, sequential per-lane sums, hsum fold.
    Returns one [S] f32 array per input term array."""
    out = []
    for t in terms:
        sblocks, n = t.shape
        chunks = t.reshape(sblocks, n // LANES, LANES)
        acc = np.zeros((sblocks, LANES), dtype=F32)
        for c in range(n // LANES):
            acc = acc + chunks[:, c, :]
        out.append(_lane_hsum(acc))
    return out


def make_qx_quants_scales(x: np.ndarray, nmax: int, weights) -> np.ndarray:
    """Vectorized `make_qx_quants` over [S, n] sub-blocks, returning the
    per-sub-block scale. (The emitted codes are re-rounded by every
    caller against the quantized scale, so only the scale matters.)"""
    S, n = x.shape
    absx = np.abs(x)
    amax = np.max(absx, axis=1)
    # Signed value at the first index attaining the max |x| (the Rust
    # fold only replaces on strictly-greater).
    maxv = x[np.arange(S), np.argmax(absx, axis=1)]
    degenerate = amax < F32(1e-30)
    safe_max = np.where(degenerate, F32(1.0), maxv)

    lo, hi = -float(nmax), float(nmax - 1)
    if weights is None:
        w = x * x + F32(1e-8)
    else:
        w = weights + F32(1e-10)

    best_scale = np.zeros(S, dtype=F32)
    best_metric = np.zeros(S, dtype=F32)
    nmax_f = F32(float(nmax))
    with np.errstate(divide="ignore", invalid="ignore"):
        for step in range(-9, 10):
            cand = F32(nmax_f + F32(F32(0.1) * F32(float(step))))
            iscale = (-cand) / safe_max
            q = qround(iscale[:, None] * x, lo, hi)
            sumlx, suml2 = _lane_sums([(w * x) * q, (w * q) * q])
            skip = suml2 <= 0.0
            scale = sumlx / suml2
            metric = scale * sumlx
            better = (~skip) & (metric > best_metric)
            best_metric = np.where(better, metric, best_metric)
            best_scale = np.where(better, scale, best_scale)
    fallback = best_scale == 0.0
    best_scale = np.where(fallback, maxv / (-nmax_f), best_scale)
    return np.where(degenerate, F32(0.0), best_scale).astype(F32)


def make_qkx_quants_scales(x: np.ndarray, nmax: int, weights):
    """Vectorized `make_qkx_quants` over [S, n] sub-blocks, returning
    per-sub-block `(scale, min)` (codes are re-rounded by callers)."""
    S, n = x.shape
    vmin0 = np.min(x, axis=1)
    vmax = np.max(x, axis=1)
    degenerate = vmax <= (vmin0 + F32(1e-30))
    deg_scale = np.where(vmin0 >= 0.0, vmin0 / F32(float(nmax)), F32(0.0))
    deg_min = np.where(vmin0 >= 0.0, F32(0.0), -vmin0)

    vmin = np.where(vmin0 > 0.0, F32(0.0), vmin0)
    span = vmax - vmin
    safe_span = np.where(degenerate, F32(1.0), span)
    hi = float(nmax)
    if weights is None:
        w = x * x + F32(1e-8)
    else:
        w = weights + F32(1e-10)

    nmax_f = F32(float(nmax))
    best = span / nmax_f
    best_min = -vmin
    best_err = np.full(S, np.inf, dtype=F32)
    two = F32(2.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        for step in range(-5, 9):
            cand = F32(F32(F32(0.1) * F32(float(step))) + nmax_f)
            iscale = cand / safe_span
            q = qround(iscale[:, None] * (x - vmin[:, None]), 0.0, hi)
            sw, sx, sl, sl2, sxl = _lane_sums(
                [w, w * x, w * q, (w * q) * q, (w * x) * q]
            )
            det = (sw * sl2) - (sl * sl)
            skip = det <= 0.0
            scale = ((sw * sxl) - (sx * sl)) / det
            minv = ((sl2 * sx) - (sl * sxl)) / det
            pos = minv > 0.0
            alt = np.where(sl2 > 0.0, sxl / sl2, scale)
            scale = np.where(pos, alt, scale)
            minv = np.where(pos, F32(0.0), minv)
            skip = skip | (scale <= 0.0)
            err = (
                ((scale * scale) * sl2)
                + (((two * scale) * minv) * sl)
                + ((minv * minv) * sw)
                - ((two * scale) * sxl)
                - ((two * minv) * sx)
            )
            better = (~skip) & (err < best_err)
            best = np.where(better, scale, best)
            best_min = np.where(better, -minv, best_min)
            best_err = np.where(better, err, best_err)
    scale = np.where(degenerate, deg_scale, best).astype(F32)
    mn = np.where(degenerate, deg_min, best_min).astype(F32)
    return scale, mn


# --- scalar transcription (independent check of the vectorized search) ---


def _hsum_scalar(acc):
    s = F32(0.0)
    for v in acc:
        s = F32(s + v)
    return s


def _qround_scalar(v, lo, hi):
    vv = float(v)
    r = math.floor(vv + 0.5) if vv >= 0.0 else math.ceil(vv - 0.5)
    return F32(min(max(F32(r), F32(lo)), F32(hi)))


def make_qx_quants_scalar(x, nmax, weights):
    amax = F32(0.0)
    maxv = F32(0.0)
    for v in x:
        if abs(v) > amax:
            amax = abs(v)
            maxv = v
    if amax < F32(1e-30):
        return F32(0.0)
    lo, hi = -float(nmax), float(nmax - 1)
    best_scale = F32(0.0)
    best_metric = F32(0.0)
    for step in range(-9, 10):
        iscale = F32(-F32(F32(float(nmax)) + F32(F32(0.1) * F32(float(step)))) / maxv)
        sumlx = [F32(0.0)] * LANES
        suml2 = [F32(0.0)] * LANES
        for i, xv in enumerate(x):
            q = _qround_scalar(F32(iscale * xv), lo, hi)
            w = (
                F32(F32(xv * xv) + F32(1e-8))
                if weights is None
                else F32(weights[i] + F32(1e-10))
            )
            lane = i % LANES
            sumlx[lane] = F32(sumlx[lane] + F32(F32(w * xv) * q))
            suml2[lane] = F32(suml2[lane] + F32(F32(w * q) * q))
        slx, sl2 = _hsum_scalar(sumlx), _hsum_scalar(suml2)
        if sl2 <= 0.0:
            continue
        scale = F32(slx / sl2)
        metric = F32(scale * slx)
        if metric > best_metric:
            best_metric = metric
            best_scale = scale
    if best_scale == 0.0:
        best_scale = F32(maxv / -F32(float(nmax)))
    return best_scale


def make_qkx_quants_scalar(x, nmax, weights):
    vmin = x[0]
    vmax = x[0]
    for v in x:
        vmin = min(vmin, v)
        vmax = max(vmax, v)
    if vmax <= F32(vmin + F32(1e-30)):
        if vmin >= 0.0:
            return F32(vmin / F32(float(nmax))), F32(0.0)
        return F32(0.0), F32(-vmin)
    if vmin > 0.0:
        vmin = F32(0.0)
    hi = float(nmax)
    best = F32(F32(vmax - vmin) / F32(float(nmax)))
    best_min = F32(-vmin)
    best_err = F32(np.inf)
    for step in range(-5, 9):
        iscale = F32(
            F32(F32(F32(0.1) * F32(float(step))) + F32(float(nmax))) / F32(vmax - vmin)
        )
        sw = [F32(0.0)] * LANES
        sx = [F32(0.0)] * LANES
        sl = [F32(0.0)] * LANES
        sl2 = [F32(0.0)] * LANES
        sxl = [F32(0.0)] * LANES
        for i, xv in enumerate(x):
            q = _qround_scalar(F32(iscale * F32(xv - vmin)), 0.0, hi)
            w = (
                F32(F32(xv * xv) + F32(1e-8))
                if weights is None
                else F32(weights[i] + F32(1e-10))
            )
            lane = i % LANES
            sw[lane] = F32(sw[lane] + w)
            sx[lane] = F32(sx[lane] + F32(w * xv))
            sl[lane] = F32(sl[lane] + F32(w * q))
            sl2[lane] = F32(sl2[lane] + F32(F32(w * q) * q))
            sxl[lane] = F32(sxl[lane] + F32(F32(w * xv) * q))
        s_w, s_x, s_l, s_l2, s_xl = (
            _hsum_scalar(sw),
            _hsum_scalar(sx),
            _hsum_scalar(sl),
            _hsum_scalar(sl2),
            _hsum_scalar(sxl),
        )
        det = F32(F32(s_w * s_l2) - F32(s_l * s_l))
        if det <= 0.0:
            continue
        scale = F32(F32(F32(s_w * s_xl) - F32(s_x * s_l)) / det)
        minv = F32(F32(F32(s_l2 * s_x) - F32(s_l * s_xl)) / det)
        if minv > 0.0:
            minv = F32(0.0)
            scale = F32(s_xl / s_l2) if s_l2 > 0.0 else scale
        if scale <= 0.0:
            continue
        err = F32(
            F32(
                F32(
                    F32(F32(F32(scale * scale) * s_l2))
                    + F32(F32(F32(F32(2.0) * scale) * minv) * s_l)
                )
                + F32(F32(minv * minv) * s_w)
            )
            - F32(F32(F32(2.0) * scale) * s_xl)
        )
        err = F32(err - F32(F32(F32(2.0) * minv) * s_x))
        if err < best_err:
            best_err = err
            best = scale
            best_min = F32(-minv)
    return best, best_min


# ---------------------------------------------------------------------------
# Block packers (mirrors of rust/src/quant/{q2k,q3k,q4k,q5k,q6k,q8_0,raw}.rs)
# ---------------------------------------------------------------------------

QK_K = 256
QK8_0 = 32


def _sub(x: np.ndarray, sub: int) -> np.ndarray:
    """[nblocks, 256] → [nblocks·(256/sub), sub] sub-block view."""
    return x.reshape(-1, sub)


def encode_q8_0(x: np.ndarray, _imp) -> np.ndarray:
    xb = x.reshape(-1, QK8_0)
    nb = xb.shape[0]
    amax = np.max(np.abs(xb), axis=1)
    d = amax / F32(127.0)
    inv0 = np.where(d > 0.0, F32(1.0) / np.where(d > 0.0, d, F32(1.0)), F32(0.0))
    dbits = f32_to_f16_bits(d)
    ds = f16_bits_to_f32(dbits)
    inv = np.where(ds > 0.0, F32(1.0) / np.where(ds > 0.0, ds, F32(1.0)), inv0)
    codes = np.clip(nearest_int(xb * inv[:, None]), -127, 127).astype(np.int8)
    out = np.zeros((nb, 34), dtype=np.uint8)
    out[:, 0] = (dbits & 0xFF).astype(np.uint8)
    out[:, 1] = (dbits >> 8).astype(np.uint8)
    out[:, 2:] = codes.view(np.uint8)
    return out.reshape(-1)


def _qkx_format(x, imp, nmax, nsc):
    """Shared q2k/q4k/q5k head: per-sub-block (scale, min) search, f16
    super-scales (`max/nsc`), quantized sub-scales, re-rounded codes.
    Returns (d, dmin, sc, mn, codes[nb, 256])."""
    sub = QK_K // (16 if nmax == 3 else 8)
    xs = _sub(x, sub)
    ws = None if imp is None else _sub(imp, sub)
    scales, mins = make_qkx_quants_scales(xs, nmax, ws)
    nsub = QK_K // sub
    scales = scales.reshape(-1, nsub)
    mins = mins.reshape(-1, nsub)
    max_scale = np.max(scales, axis=1)
    max_min = np.max(mins, axis=1)
    d_raw = np.where(max_scale > 0.0, max_scale / F32(float(nsc)), F32(0.0))
    dmin_raw = np.where(max_min > 0.0, max_min / F32(float(nsc)), F32(0.0))
    dbits = f32_to_f16_bits(d_raw)
    dminbits = f32_to_f16_bits(dmin_raw)
    d = f16_bits_to_f32(dbits)
    dmin = f16_bits_to_f32(dminbits)
    with np.errstate(divide="ignore", invalid="ignore"):
        sc = np.where(
            (d > 0.0)[:, None],
            np.clip(nearest_int(scales / np.where(d > 0.0, d, F32(1.0))[:, None]), 0, nsc),
            0,
        ).astype(np.uint8)
        mn = np.where(
            (dmin > 0.0)[:, None],
            np.clip(
                nearest_int(mins / np.where(dmin > 0.0, dmin, F32(1.0))[:, None]), 0, nsc
            ),
            0,
        ).astype(np.uint8)
    sd = d[:, None] * sc.astype(F32)  # [nb, nsub]
    sm = dmin[:, None] * mn.astype(F32)
    xb = x.reshape(-1, nsub, sub)
    with np.errstate(divide="ignore", invalid="ignore"):
        codes = np.clip(
            nearest_int((xb + sm[:, :, None]) / sd[:, :, None]), 0, nmax
        ).astype(np.uint8)
    codes = np.where((sd > 0.0)[:, :, None], codes, np.uint8(0)).reshape(-1, QK_K)
    return dbits, dminbits, sc, mn, codes


def _pack_scale_min_6(sc, mn):
    """q4k::pack_scale_min_6 — [nb, 8]+[nb, 8] 6-bit values → [nb, 12]."""
    nb = sc.shape[0]
    out = np.zeros((nb, 12), dtype=np.uint8)
    out[:, :8] = (sc & 0x3F) | ((mn & 0x03) << 6)
    for k in range(4):
        out[:, 8 + k] = (mn[:, 2 * k] >> 2) | ((mn[:, 2 * k + 1] >> 2) << 4)
    return out


def encode_q4k_q5k(x, imp, nmax, block_bytes, qs_off, high_bit):
    dbits, dminbits, sc, mn, codes = _qkx_format(x, imp, nmax, 63)
    nb = codes.shape[0]
    out = np.zeros((nb, block_bytes), dtype=np.uint8)
    out[:, 0] = (dbits & 0xFF).astype(np.uint8)
    out[:, 1] = (dbits >> 8).astype(np.uint8)
    out[:, 2] = (dminbits & 0xFF).astype(np.uint8)
    out[:, 3] = (dminbits >> 8).astype(np.uint8)
    out[:, 4:16] = _pack_scale_min_6(sc, mn)
    lo = codes & 0x0F
    out[:, qs_off : qs_off + 128] = lo[:, 0::2] | (lo[:, 1::2] << 4)
    if high_bit:
        hi = (codes >> 4) & 1
        qh = np.zeros((nb, 32), dtype=np.uint8)
        for bit in range(8):
            qh |= hi[:, bit::8] << bit
        out[:, 16:48] = qh
    return out.reshape(-1)


def encode_q4k(x, imp):
    return encode_q4k_q5k(x, imp, 15, 144, 16, False)


def encode_q5k(x, imp):
    return encode_q4k_q5k(x, imp, 31, 176, 48, True)


def encode_q2k(x, imp):
    dbits, dminbits, sc, mn, codes = _qkx_format(x, imp, 3, 15)
    nb = codes.shape[0]
    out = np.zeros((nb, 84), dtype=np.uint8)
    out[:, :16] = sc | (mn << 4)
    lo = codes & 0x03
    out[:, 16:80] = lo[:, 0::4] | (lo[:, 1::4] << 2) | (lo[:, 2::4] << 4) | (lo[:, 3::4] << 6)
    out[:, 80] = (dbits & 0xFF).astype(np.uint8)
    out[:, 81] = (dbits >> 8).astype(np.uint8)
    out[:, 82] = (dminbits & 0xFF).astype(np.uint8)
    out[:, 83] = (dminbits >> 8).astype(np.uint8)
    return out.reshape(-1)


def _qx_format(x, imp, nmax):
    """Shared q3k/q6k head: symmetric per-sub-block scale search.
    Returns [nb, 16] scales (f32)."""
    xs = _sub(x, 16)
    ws = None if imp is None else _sub(imp, 16)
    scales = make_qx_quants_scales(xs, nmax, ws)
    return scales.reshape(-1, 16)


def _pack_scales_6x16(sc):
    """q3k::pack_scales_6x16 — [nb, 16] 6-bit values → [nb, 12]."""
    nb = sc.shape[0]
    out = np.zeros((nb, 12), dtype=np.uint8)
    for j in range(8):
        out[:, j] = (sc[:, j] & 0x0F) | ((sc[:, 8 + j] & 0x0F) << 4)
    for k in range(4):
        b = np.zeros(nb, dtype=np.uint8)
        for t in range(4):
            b |= ((sc[:, 4 * t + k] >> 4) & 0x03) << (2 * t)
        out[:, 8 + k] = b
    return out


def _pack_codes_q3k(codes):
    nb = codes.shape[0]
    out = np.zeros((nb, 96), dtype=np.uint8)  # [12..108) = hmask32 + qs64
    lo = codes & 0x03
    hi = (codes >> 2) & 0x01
    hm = np.zeros((nb, 32), dtype=np.uint8)
    for bit in range(8):
        hm |= hi[:, bit::8] << bit
    qs = lo[:, 0::4] | (lo[:, 1::4] << 2) | (lo[:, 2::4] << 4) | (lo[:, 3::4] << 6)
    out[:, 0:32] = hm
    out[:, 32:96] = qs
    return out


def encode_q3k(x, imp):
    scales = _qx_format(x, imp, 4)  # [nb, 16]
    nb = scales.shape[0]
    max_abs = np.max(np.abs(scales), axis=1)
    out = np.zeros((nb, 110), dtype=np.uint8)
    zero = max_abs < F32(1e-30)
    d_raw = max_abs / F32(31.0)
    dbits = f32_to_f16_bits(d_raw)
    d = f16_bits_to_f32(dbits)
    invd = np.where(d > 0.0, F32(1.0) / np.where(d > 0.0, d, F32(1.0)), F32(0.0))
    isc = np.clip(nearest_int(scales * invd[:, None]), -32, 31)
    sc6 = (isc + 32).astype(np.uint8)
    sd = d[:, None] * isc.astype(F32)  # [nb, 16]
    with np.errstate(divide="ignore", invalid="ignore"):
        inv = np.where(sd != 0.0, F32(1.0) / np.where(sd != 0.0, sd, F32(1.0)), F32(0.0))
    xb = x.reshape(-1, 16, 16)
    codes = np.clip(nearest_int(xb * inv[:, :, None]), -4, 3) + 4
    codes = np.where((sd != 0.0)[:, :, None], codes, 4).astype(np.uint8).reshape(-1, QK_K)
    # Degenerate all-zero super-blocks: sc = 32, codes = 4.
    sc6 = np.where(zero[:, None], np.uint8(32), sc6)
    codes = np.where(zero[:, None], np.uint8(4), codes)
    dbits = np.where(zero, np.uint16(0), dbits)
    out[:, 0:12] = _pack_scales_6x16(sc6)
    out[:, 12:108] = _pack_codes_q3k(codes)
    out[:, 108] = (dbits & 0xFF).astype(np.uint8)
    out[:, 109] = (dbits >> 8).astype(np.uint8)
    return out.reshape(-1)


def encode_q6k(x, imp):
    scales = _qx_format(x, imp, 32)  # [nb, 16]
    nb = scales.shape[0]
    max_abs = np.max(np.abs(scales), axis=1)
    zero = max_abs < F32(1e-30)
    d_raw = max_abs / F32(127.0)
    dbits = f32_to_f16_bits(d_raw)
    d = f16_bits_to_f32(dbits)
    invd = np.where(d > 0.0, F32(1.0) / np.where(d > 0.0, d, F32(1.0)), F32(0.0))
    isc = np.clip(nearest_int(scales * invd[:, None]), -127, 127)
    sd = d[:, None] * isc.astype(F32)
    with np.errstate(divide="ignore", invalid="ignore"):
        inv = np.where(sd != 0.0, F32(1.0) / np.where(sd != 0.0, sd, F32(1.0)), F32(0.0))
    xb = x.reshape(-1, 16, 16)
    codes = np.clip(nearest_int(xb * inv[:, :, None]), -32, 31) + 32
    codes = np.where((sd != 0.0)[:, :, None], codes, 32).astype(np.uint8).reshape(-1, QK_K)
    out = np.zeros((nb, 210), dtype=np.uint8)
    lo = codes & 0x0F
    hi = (codes >> 4) & 0x03
    out[:, 0:128] = lo[:, 0::2] | (lo[:, 1::2] << 4)
    out[:, 128:192] = (
        hi[:, 0::4] | (hi[:, 1::4] << 2) | (hi[:, 2::4] << 4) | (hi[:, 3::4] << 6)
    )
    out[:, 192:208] = isc.astype(np.int8).view(np.uint8)
    out[:, 208] = (dbits & 0xFF).astype(np.uint8)
    out[:, 209] = (dbits >> 8).astype(np.uint8)
    # Degenerate super-blocks are entirely zeroed (`ob.fill(0)`).
    out[zero] = 0
    return out.reshape(-1)


def encode_f32(x, _imp):
    return np.ascontiguousarray(x, dtype=F32).view(np.uint8).copy()


def encode_f16(x, _imp):
    return f32_to_f16_bits(x).view(np.uint8).copy()


ENCODERS = {
    "f32": encode_f32,
    "f16": encode_f16,
    "q8_0": encode_q8_0,
    "q6_k": encode_q6k,
    "q5_k": encode_q5k,
    "q4_k": encode_q4k,
    "q3_k": encode_q3k,
    "q2_k": encode_q2k,
}

BLOCK_BYTES = pyquants.BLOCK_BYTES
BLOCK_WEIGHTS = dict(pyquants.BLOCK_WEIGHTS)
BLOCK_WEIGHTS["f16"] = 1  # quants.py's table entry is a quirky `2 // 2`


def quantize(fmt: str, data: np.ndarray, imp=None) -> np.ndarray:
    payload = ENCODERS[fmt](data, imp)
    expect = pyquants.row_bytes(fmt, data.size)
    assert payload.size == expect, (fmt, payload.size, expect)
    return payload


# ---------------------------------------------------------------------------
# Fixture generation (mirrors tests/golden_vectors.rs)
# ---------------------------------------------------------------------------

NBLOCKS = 3
FORMATS = ["f32", "f16", "q8_0", "q6_k", "q5_k", "q4_k", "q3_k", "q2_k"]


def golden_input(fmt: str):
    n = BLOCK_WEIGHTS[fmt] * NBLOCKS
    rng = Pcg(0x601D ^ (BLOCK_BYTES[fmt] << 16))
    data = rng.normals(n, 0.1)
    data[0] = F32(0.0)
    if n >= 8:
        data[5] = F32(1.5)
        data[6] = F32(-2.25)
        data[7] = F32(0.0)
    imp = np.array([F32(rng.next_f32() + F32(0.1)) for _ in range(n)], dtype=F32)
    return data, imp


def hex_fixture(payload: np.ndarray) -> str:
    b = bytes(payload)
    lines = []
    for i in range(0, len(b), 32):
        lines.append(b[i : i + 32].hex())
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Container golden (mirrors synthetic_f32_container + quantize_container)
# ---------------------------------------------------------------------------

TINY_MOE = dict(
    name="tiny-moe",
    kind="mla_moe",
    vocab_size=512,
    hidden_size=256,
    n_layers=6,
    first_dense=1,
    n_heads=4,
    n_kv_heads=4,
    head_dim=0,
    q_lora_rank=256,
    kv_lora_rank=256,
    qk_nope_head_dim=32,
    qk_rope_head_dim=32,
    v_head_dim=64,
    intermediate_size=512,
    moe_intermediate_size=256,
    n_routed_experts=8,
    n_shared_experts=1,
    n_active_experts=2,
)


def tiny_moe_census():
    """Mirror of ModelConfig::census for the MLA+MoE tiny model."""
    c = TINY_MOE
    out = [("token_embd.weight", "token_embd", None, [c["vocab_size"], c["hidden_size"]])]
    h = c["hidden_size"]
    for i in range(c["n_layers"]):
        blk = lambda stem: f"blk.{i}.{stem}.weight"  # noqa: E731
        out.append((blk("attn_norm"), "norm", i, [h]))
        qk_head = c["qk_nope_head_dim"] + c["qk_rope_head_dim"]
        out.append((blk("attn_q_a"), "attn_q_a", i, [c["q_lora_rank"], h]))
        out.append((blk("attn_q_a_norm"), "norm", i, [c["q_lora_rank"]]))
        out.append((blk("attn_q_b"), "attn_q_b", i, [c["n_heads"] * qk_head, c["q_lora_rank"]]))
        out.append(
            (
                blk("attn_kv_a_mqa"),
                "attn_kv_a_mqa",
                i,
                [c["kv_lora_rank"] + c["qk_rope_head_dim"], h],
            )
        )
        out.append((blk("attn_kv_a_norm"), "norm", i, [c["kv_lora_rank"]]))
        out.append(
            (
                blk("attn_kv_b"),
                "attn_kv_b",
                i,
                [c["n_heads"] * (c["qk_nope_head_dim"] + c["v_head_dim"]), c["kv_lora_rank"]],
            )
        )
        out.append((blk("attn_output"), "attn_output", i, [h, c["n_heads"] * c["v_head_dim"]]))
        out.append((blk("ffn_norm"), "norm", i, [h]))
        if i >= c["first_dense"]:
            mi = c["moe_intermediate_size"]
            out.append((blk("ffn_gate_inp"), "ffn_gate_inp", i, [c["n_routed_experts"], h]))
            out.append((blk("ffn_gate_exps"), "ffn_gate_exps", i, [c["n_routed_experts"], mi, h]))
            out.append((blk("ffn_up_exps"), "ffn_up_exps", i, [c["n_routed_experts"], mi, h]))
            out.append((blk("ffn_down_exps"), "ffn_down_exps", i, [c["n_routed_experts"], h, mi]))
            sh = c["n_shared_experts"] * mi
            out.append((blk("ffn_gate_shexp"), "ffn_gate_shexp", i, [sh, h]))
            out.append((blk("ffn_up_shexp"), "ffn_up_shexp", i, [sh, h]))
            out.append((blk("ffn_down_shexp"), "ffn_down_shexp", i, [h, sh]))
        else:
            out.append((blk("ffn_gate"), "ffn_gate", i, [c["intermediate_size"], h]))
            out.append((blk("ffn_up"), "ffn_up", i, [c["intermediate_size"], h]))
            out.append((blk("ffn_down"), "ffn_down", i, [h, c["intermediate_size"]]))
    out.append(("output_norm.weight", "norm", None, [h]))
    out.append(("output.weight", "output", None, [c["vocab_size"], c["hidden_size"]]))
    return out


TINY_DENSE = dict(
    name="tiny-dense",
    kind="dense_gqa",
    vocab_size=512,
    hidden_size=256,
    n_layers=3,
    first_dense=3,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    q_lora_rank=0,
    kv_lora_rank=0,
    qk_nope_head_dim=0,
    qk_rope_head_dim=0,
    v_head_dim=0,
    intermediate_size=512,
    moe_intermediate_size=0,
    n_routed_experts=0,
    n_shared_experts=0,
    n_active_experts=0,
    rope_base=1000000,
)


def tiny_dense_census():
    """Mirror of ModelConfig::census for the dense-GQA tiny model."""
    c = TINY_DENSE
    h = c["hidden_size"]
    out = [("token_embd.weight", "token_embd", None, [c["vocab_size"], h])]
    for i in range(c["n_layers"]):
        blk = lambda stem: f"blk.{i}.{stem}.weight"  # noqa: E731
        out.append((blk("attn_norm"), "norm", i, [h]))
        out.append((blk("attn_q"), "attn_q", i, [c["n_heads"] * c["head_dim"], h]))
        out.append((blk("attn_k"), "attn_k", i, [c["n_kv_heads"] * c["head_dim"], h]))
        out.append((blk("attn_v"), "attn_v", i, [c["n_kv_heads"] * c["head_dim"], h]))
        out.append((blk("attn_output"), "attn_output", i, [h, c["n_heads"] * c["head_dim"]]))
        out.append((blk("ffn_norm"), "norm", i, [h]))
        out.append((blk("ffn_gate"), "ffn_gate", i, [c["intermediate_size"], h]))
        out.append((blk("ffn_up"), "ffn_up", i, [c["intermediate_size"], h]))
        out.append((blk("ffn_down"), "ffn_down", i, [h, c["intermediate_size"]]))
    out.append(("output_norm.weight", "norm", None, [h]))
    out.append(("output.weight", "output", None, [c["vocab_size"], c["hidden_size"]]))
    return out


def load_scheme(name: str) -> dict:
    return json.loads((REPO / "configs" / "schemes" / f"{name}.json").read_text())


def use_more_bits(i_layer: int, n_layer: int) -> bool:
    return (
        i_layer < n_layer // 8
        or i_layer >= 7 * n_layer // 8
        or (i_layer - n_layer // 8) % 3 == 2
    )


def assign(scheme: dict, cls: str, layer, shape, model=TINY_MOE) -> str:
    """Mirror of Scheme::assign (incl. the ragged-row f16 fallback)."""
    if cls in ("norm", "ffn_gate_inp"):
        return "f32"
    rule = next((r for r in scheme["rules"] if r["module"] == cls), None)
    if rule is None:
        fmt = scheme["default"]
    elif "format" in rule:
        fmt = rule["format"]
    elif "more_bits" in rule:
        li = layer if layer is not None else 0
        fmt = rule["more_bits"]["high" if use_more_bits(li, model["n_layers"]) else "low"]
    else:
        dy = rule["dynamic"]
        li = layer if layer is not None else 0
        moe_idx = max(0, li - model["first_dense"])
        if moe_idx < dy["first_moe"]:
            fmt = dy["first_format"]
        elif dy["period"] > 0 and li % dy["period"] == 0:
            fmt = dy["period_format"]
        else:
            fmt = dy["default"]
    bw = BLOCK_WEIGHTS[fmt]
    n_params = int(np.prod(shape))
    if shape[-1] % bw != 0 or n_params % bw != 0:
        return "f16"
    return fmt


def quantize_census(scheme_name: str, tensor_values: dict, census=None, model=TINY_MOE) -> list[dict]:
    """Quantize every census tensor under `scheme_name`, returning
    per-tensor dicts with the encoded payload (shared by the container
    serializer and the forward-pass mirror)."""
    scheme = load_scheme(scheme_name)
    out = []
    for name, cls, layer, shape in census if census is not None else tiny_moe_census():
        fmt = assign(scheme, cls, layer, shape, model)
        out.append(
            {
                "name": name,
                "class": cls,
                "layer": layer,
                "shape": shape,
                "format": fmt,
                "payload": quantize(fmt, tensor_values[name]),
            }
        )
    return out


def build_container(scheme_name: str, quantized: list[dict], model=TINY_MOE) -> bytes:
    """Serialize the quantized container exactly as the Rust Writer.

    `model` must mirror ModelConfig::to_json field-for-field — note the
    Rust side **omits** `rope_base` at the default θ=10000 (TINY_MOE
    accordingly has no such key) and appends it last otherwise
    (TINY_DENSE carries `rope_base=1000000` as its final key)."""
    entries = []
    data = bytearray()
    for q in quantized:
        payload = bytes(q["payload"])
        aligned = -(-len(data) // 64) * 64
        data.extend(b"\0" * (aligned - len(data)))
        entries.append(
            {
                "name": q["name"],
                "class": q["class"],
                "layer": q["layer"],
                "shape": q["shape"],
                "format": q["format"],
                "offset": aligned,
                "nbytes": len(payload),
            }
        )
        data.extend(payload)
    header = json.dumps(
        {
            "version": 1,
            "model": model,
            "scheme": scheme_name,
            "meta": {},
            "tensors": entries,
        },
        separators=(",", ":"),
    ).encode()
    data_start = -(-(8 + len(header)) // 4096) * 4096
    out = bytearray()
    out += b"DSQ1"
    out += len(header).to_bytes(4, "little")
    out += header
    out += b"\0" * (data_start - len(out))
    out += data
    return bytes(out)


def fnv64(b: bytes) -> int:
    h = 0xCBF29CE484222325
    for byte in b:
        h ^= byte
        h = (h * 0x100000001B3) & MASK64
    return h


# ---------------------------------------------------------------------------
# util::math mirror — deterministic f32 transcendentals
# (see rust/src/util/math.rs; every op below is a single-rounded f32
# add/mul/div/sqrt or a bit manipulation, replayed in np.float32)
# ---------------------------------------------------------------------------

_LOG2E = F32("1.4426950408889634")
_LN2_HI = F32("0.693359375")
_LN2_LO = F32("-0.00021219444")
_EXP_P = [
    F32(c)
    for c in (
        "1.0",
        "1.0",
        "0.5",
        "0.16666667",
        "0.041666667",
        "0.0083333333",
        "0.0013888889",
        "0.00019841270",
    )
]
_SIN_P = [F32(c) for c in ("-0.16666667", "0.0083333333", "-0.00019841270", "0.0000027557319")]
_COS_P = [F32(c) for c in ("-0.5", "0.041666667", "-0.0013888889", "0.000024801587")]
_RMS_EPS = F32("1e-6")
# Exact f64 constants of rust std (sqrt 2 / ln 2, correctly rounded).
_SQRT2_F64 = float.fromhex("0x1.6a09e667f3bcdp+0")
_LN2_F64 = float.fromhex("0x1.62e42fefa39efp-1")


def ln_f32(x: float) -> np.float32:
    """Bit-exact mirror of util::math::ln_f32 — every operation below is
    an IEEE-double add/mul/div (CPython floats), identical to the Rust
    f64 sequence, so both sides produce the same f32 bits."""
    bits = struct.unpack("<Q", struct.pack("<d", float(x)))[0]
    e = ((bits >> 52) & 0x7FF) - 1023
    m = struct.unpack(
        "<d", struct.pack("<Q", (bits & 0x000F_FFFF_FFFF_FFFF) | (1023 << 52))
    )[0]
    if m > _SQRT2_F64:
        m *= 0.5
        e += 1
    s = (m - 1.0) / (m + 1.0)
    s2 = s * s
    p = 0.0
    for k in range(12, 0, -1):
        p = p * s2 + 1.0 / (2 * k + 1)
    ln_m = 2.0 * s * (1.0 + s2 * p)
    return F32(e * _LN2_F64 + ln_m)


def _round_ties_away(v: np.ndarray) -> np.ndarray:
    """f32::round — ties away from zero (same trick as qround)."""
    v64 = np.asarray(v, dtype=np.float64)
    return np.where(v64 >= 0.0, np.floor(v64 + 0.5), np.ceil(v64 - 0.5)).astype(F32)


def exp_f32(x) -> np.ndarray:
    """Vectorized mirror of util::math::exp_f32."""
    x = np.minimum(np.maximum(np.asarray(x, dtype=F32), F32(-87.0)), F32(88.0))
    n = _round_ties_away(x * _LOG2E)
    r = (x - n * _LN2_HI) - n * _LN2_LO
    p = np.full_like(r, _EXP_P[7])
    for k in range(6, -1, -1):
        p = p * r + _EXP_P[k]
    scale = ((n.astype(np.int64) + 127).astype(np.uint32) << np.uint32(23)).view(F32)
    return p * scale


def _sin_small(x: np.float32) -> np.float32:
    t = F32(x * x)
    p = _SIN_P[3]
    for k in (2, 1, 0):
        p = F32(F32(p * t) + _SIN_P[k])
    return F32(x + F32(F32(x * t) * p))


def _cos_small(x: np.float32) -> np.float32:
    t = F32(x * x)
    p = _COS_P[3]
    for k in (2, 1, 0):
        p = F32(F32(p * t) + _COS_P[k])
    return F32(F32(1.0) + F32(t * p))


def softmax_f32(x: np.ndarray) -> np.ndarray:
    """Mirror of util::math::softmax_in_place: front-to-back max fold,
    exp+sum in index order, then divide."""
    m = np.max(x)  # exact max — order-independent
    e = exp_f32(x - m)
    s = F32(0.0)
    for v in e:
        s = F32(s + v)
    return e / s


# ---------------------------------------------------------------------------
# runtime::forward mirror — the tiny-MoE forward pass on decoded blocks
# ---------------------------------------------------------------------------
#
# The Rust engine computes every matvec with the fused vec_dot kernels,
# whose contract is bit-identity with `dot_lanes` over the decoded
# blocks; python/compile/quants.py decodes bit-exactly (same op order
# as the Rust format modules), so the mirror decodes each tensor once
# and replays the canonical lane reduction: element i → lane i%8,
# sequential per-lane f32 sums, hsum fold starting from +0.0.


def lane_matvec(w: np.ndarray, x: np.ndarray) -> np.ndarray:
    """[rows, n]·[n] in the canonical lane order (n % 8 == 0 on every
    forward-pass shape)."""
    prods = w * x[None, :]
    rows, n = prods.shape
    chunks = prods.reshape(rows, n // LANES, LANES)
    acc = np.zeros((rows, LANES), dtype=F32)
    for c in range(n // LANES):
        acc = acc + chunks[:, c, :]
    s = np.zeros(rows, dtype=F32)  # hsum starts from +0.0
    for lane in range(LANES):
        s = s + acc[:, lane]
    return s


def lane_dot(a: np.ndarray, b: np.ndarray) -> np.float32:
    return lane_matvec(a.reshape(1, -1), b)[0]


def rms_norm_f32(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    ss = lane_dot(x, x)
    ms = F32(F32(ss / F32(float(x.size))) + _RMS_EPS)
    scale = F32(F32(1.0) / np.float32(np.sqrt(ms)))
    return (x * scale) * w


class RopeMirror:
    """Mirror of runtime::forward::RopeTable (frequencies from the
    model's rope_base via the bit-exact ln_f32 mirror)."""

    def __init__(self, dim: int, max_ctx: int, base_ln: np.float32):
        half = dim // 2
        self.half = half
        self.cos = np.zeros((max_ctx, half), dtype=F32)
        self.sin = np.zeros((max_ctx, half), dtype=F32)
        for i in range(half):
            a = F32(F32(float(2 * i)) / F32(float(dim)))
            theta = F32(exp_f32(np.array([F32(-F32(a * base_ln))], dtype=F32))[0])
            c1, s1 = _cos_small(theta), _sin_small(theta)
            c, s = F32(1.0), F32(0.0)
            for p in range(max_ctx):
                self.cos[p, i] = c
                self.sin[p, i] = s
                cn = F32(F32(c * c1) - F32(s * s1))
                sn = F32(F32(s * c1) + F32(c * s1))
                c, s = cn, sn

    def apply(self, x: np.ndarray, pos: int) -> np.ndarray:
        # Half-split (NeoX) pairing: frequency i rotates (x[i], x[i+half]),
        # matching python/compile/model.py::rope and Qwen checkpoints.
        a, b = x[: self.half], x[self.half :]
        c, s = self.cos[pos], self.sin[pos]
        out = np.empty_like(x)
        out[: self.half] = a * c - b * s
        out[self.half :] = a * s + b * c
        return out


def q8_kv_roundtrip(row: np.ndarray) -> np.ndarray:
    """Encode one KV line to q8_0 and decode it back — the exact
    transform the Rust quantize-on-append cache applies to each staged
    row (quant::encode_kv_line then per-read decode).  Rows whose width
    is not a multiple of 32 are zero-padded to the block grid before
    encoding and truncated after decoding, mirroring
    KvScheme::line_weights."""
    padded = (row.size + QK8_0 - 1) // QK8_0 * QK8_0
    staged = np.zeros(padded, dtype=F32)
    staged[: row.size] = row
    payload = encode_q8_0(staged, None)
    return pyquants.dequantize("q8_0", payload, padded)[: row.size].astype(F32)


class ForwardMirror:
    """Bit-exact mirror of runtime::forward::ForwardPass over a
    quantized tiny-model census — MLA+MoE (tiny-moe) or dense GQA
    (tiny-dense) — with weights decoded once via the
    python/compile/quants.py unpackers."""

    def __init__(
        self, quantized: list[dict], model=TINY_MOE, max_ctx: int = 24, kv_scheme: str = "f32"
    ):
        assert kv_scheme in ("f32", "q8_0"), kv_scheme
        self.c = model
        self.max_ctx = max_ctx
        self.kv_scheme = kv_scheme
        # Absorbed-MLA expanded-row cache (per layer, filled once per
        # position at append time) — only used under a quantized KV
        # scheme, where the Rust cache stores the expansion of the
        # *exact* staged latent as its own encoded row instead of
        # recomputing it from the (lossy) cached latent.
        self.xc: list[np.ndarray] | None = None
        self.w = {}
        for q in quantized:
            n = int(np.prod(q["shape"]))
            raw = np.frombuffer(bytes(q["payload"]), dtype=np.uint8)
            self.w[q["name"]] = pyquants.dequantize(q["format"], raw, n).reshape(q["shape"])
        rope_dim = (
            model["head_dim"] if model["kind"] == "dense_gqa" else model["qk_rope_head_dim"]
        )
        self.rope = RopeMirror(rope_dim, max_ctx, ln_f32(model.get("rope_base", 10000)))

    def kv_width(self) -> int:
        if self.c["kind"] == "dense_gqa":
            return 2 * self.c["n_kv_heads"] * self.c["head_dim"]
        return self.c["kv_lora_rank"] + self.c["qk_rope_head_dim"]

    def _lw(self, li: int, stem: str) -> np.ndarray:
        return self.w[f"blk.{li}.{stem}.weight"]

    def _mlp(self, gate_w, up_w, down_w, xn):
        g = lane_matvec(gate_w, xn)
        u = lane_matvec(up_w, xn)
        sig = F32(1.0) / (F32(1.0) + exp_f32(-g))  # sigmoid via exp_f32
        a = (g * sig) * u  # silu(g) · u, in the Rust op order
        return lane_matvec(down_w, a)

    def _attention(self, li, xn, cache, pos):
        if self.c["kind"] == "dense_gqa":
            return self._attention_gqa(li, xn, cache, pos)
        return self._attention_mla(li, xn, cache, pos)

    def _attention_gqa(self, li, xn, cache, pos):
        """Mirror of ForwardPass::attention_gqa: conventional per-head
        K/V cache (post-RoPE K then V), query-head groups sharing each
        KV head, RoPE over the full head dimension."""
        c = self.c
        hd, n_kv, nh = c["head_dim"], c["n_kv_heads"], c["n_heads"]
        kd = n_kv * hd
        group = nh // n_kv
        q = lane_matvec(self._lw(li, "attn_q"), xn)
        k = lane_matvec(self._lw(li, "attn_k"), xn)
        v = lane_matvec(self._lw(li, "attn_v"), xn)
        for kh in range(n_kv):
            k[kh * hd : (kh + 1) * hd] = self.rope.apply(k[kh * hd : (kh + 1) * hd], pos)
        if self.kv_scheme == "q8_0":
            # Quantize-on-append: the staged [roped-K | V] row is
            # encoded once and every later read sees the decoded form.
            cache[pos] = q8_kv_roundtrip(np.concatenate([k, v]).astype(F32))
        else:
            cache[pos, :kd] = k
            cache[pos, kd:] = v
        ctx = pos + 1
        inv = F32(F32(1.0) / np.float32(np.sqrt(F32(float(hd)))))
        heads = np.zeros(nh * hd, dtype=F32)
        for h in range(nh):
            qh = self.rope.apply(q[h * hd : (h + 1) * hd].copy(), pos)
            kh = h // group
            scores = np.zeros(ctx, dtype=F32)
            for p in range(ctx):
                scores[p] = F32(lane_dot(qh, cache[p, kh * hd : (kh + 1) * hd]) * inv)
            scores = softmax_f32(scores)
            oh = heads[h * hd : (h + 1) * hd]
            for p in range(ctx):
                oh += cache[p, kd + kh * hd : kd + (kh + 1) * hd] * scores[p]
        return lane_matvec(self._lw(li, "attn_output"), heads)

    def _attention_mla(self, li, xn, cache, pos):
        c = self.c
        nope, rope_d, vh = c["qk_nope_head_dim"], c["qk_rope_head_dim"], c["v_head_dim"]
        qk_head = nope + rope_d
        kv_rank = c["kv_lora_rank"]
        q_a = lane_matvec(self._lw(li, "attn_q_a"), xn)
        q_an = rms_norm_f32(q_a, self._lw(li, "attn_q_a_norm"))
        q = lane_matvec(self._lw(li, "attn_q_b"), q_an)
        kv_a = lane_matvec(self._lw(li, "attn_kv_a_mqa"), xn)
        latent = rms_norm_f32(kv_a[:kv_rank], self._lw(li, "attn_kv_a_norm"))
        roped = self.rope.apply(kv_a[kv_rank:], pos)
        ctx = pos + 1
        kvb_w = c["n_heads"] * (nope + vh)
        w_kvb = self._lw(li, "attn_kv_b")
        if self.kv_scheme == "q8_0":
            # Quantize-on-append, matching the Rust absorbed-MLA cache:
            # the main row [normed latent | roped rope] and the expanded
            # row W_kvb · latent (computed from the *exact* staged
            # latent, not the quantized one) are each encoded once;
            # reads below see only the decoded forms.  The quantized
            # latent segment of the main row is write-only.
            cache[pos] = q8_kv_roundtrip(np.concatenate([latent, roped]).astype(F32))
            self.xc[li][pos] = q8_kv_roundtrip(lane_matvec(w_kvb, latent))
            kvb = self.xc[li]
        else:
            cache[pos, :kv_rank] = latent
            cache[pos, kv_rank:] = roped
            kvb = np.zeros((ctx, kvb_w), dtype=F32)
            for p in range(ctx):
                kvb[p] = lane_matvec(w_kvb, cache[p, :kv_rank])
        inv = F32(F32(1.0) / np.float32(np.sqrt(F32(float(qk_head)))))
        heads = np.zeros(c["n_heads"] * vh, dtype=F32)
        for hd in range(c["n_heads"]):
            qh = q[hd * qk_head : (hd + 1) * qk_head].copy()
            qh[nope:] = self.rope.apply(qh[nope:], pos)
            scores = np.zeros(ctx, dtype=F32)
            for p in range(ctx):
                kn = kvb[p, hd * (nope + vh) : hd * (nope + vh) + nope]
                s = F32(lane_dot(qh[:nope], kn) + lane_dot(qh[nope:], cache[p, kv_rank:]))
                scores[p] = F32(s * inv)
            scores = softmax_f32(scores)
            oh = heads[hd * vh : (hd + 1) * vh]
            for p in range(ctx):
                v = kvb[p, hd * (nope + vh) + nope : hd * (nope + vh) + nope + vh]
                oh += v * scores[p]
        return lane_matvec(self._lw(li, "attn_output"), heads)

    def _ffn(self, li, xn):
        c = self.c
        if li < c["first_dense"]:
            return self._mlp(
                self._lw(li, "ffn_gate"), self._lw(li, "ffn_up"), self._lw(li, "ffn_down"), xn
            )
        probs = softmax_f32(lane_matvec(self._lw(li, "ffn_gate_inp"), xn))
        picked = sorted(
            range(c["n_routed_experts"]), key=lambda i: (-float(probs[i]), i)
        )[: c["n_active_experts"]]
        picked.sort()
        z = F32(0.0)
        for e in picked:
            z = F32(z + probs[e])
        out = self._mlp(
            self._lw(li, "ffn_gate_shexp"),
            self._lw(li, "ffn_up_shexp"),
            self._lw(li, "ffn_down_shexp"),
            xn,
        )
        for e in picked:
            w = F32(probs[e] / z)
            y = self._mlp(
                self._lw(li, "ffn_gate_exps")[e],
                self._lw(li, "ffn_up_exps")[e],
                self._lw(li, "ffn_down_exps")[e],
                xn,
            )
            out = out + y * w
        return out

    def _step(self, tok, caches, pos, want_logits):
        c = self.c
        h = self.w["token_embd.weight"][tok % c["vocab_size"]].copy()
        for li in range(c["n_layers"]):
            xn = rms_norm_f32(h, self._lw(li, "attn_norm"))
            h = h + self._attention(li, xn, caches[li], pos)
            xn = rms_norm_f32(h, self._lw(li, "ffn_norm"))
            h = h + self._ffn(li, xn)
        if not want_logits:
            return None
        xn = rms_norm_f32(h, self.w["output_norm.weight"])
        return lane_matvec(self.w["output.weight"], xn)

    def run(self, prompt: list[int], n_decode: int) -> list[np.ndarray]:
        """Prefill `prompt`, then `n_decode` greedy steps; returns the
        last-prompt-token logits followed by each decode step's logits
        (the exact rows the forward.*.fnv64 fixtures hash)."""
        c = self.c
        caches = [
            np.zeros((self.max_ctx, self.kv_width()), dtype=F32) for _ in range(c["n_layers"])
        ]
        if c["kind"] != "dense_gqa" and self.kv_scheme == "q8_0":
            kvb_w = c["n_heads"] * (c["qk_nope_head_dim"] + c["v_head_dim"])
            self.xc = [
                np.zeros((self.max_ctx, kvb_w), dtype=F32) for _ in range(c["n_layers"])
            ]
        rows = []
        pos = 0
        out = None
        for j, tok in enumerate(prompt):
            out = self._step(tok, caches, pos, j + 1 == len(prompt))
            pos += 1
        rows.append(out)
        for _ in range(n_decode):
            tok = int(np.argmax(out))
            out = self._step(tok, caches, pos, True)
            pos += 1
            rows.append(out)
        return rows


# The forward-golden script (mirrored verbatim by the Rust suite in
# rust/tests/native_forward.rs): prefill this prompt on the seed-0x601D
# tiny-moe container, then 4 greedy decode steps; hash the last-prompt
# logits row plus each decode row.
FORWARD_PROMPT = [1, 17, 300, 42, 511, 7, 5, 260]
FORWARD_DECODE_STEPS = 4


def forward_reference_f64(weights: dict, prompt, step_tokens, max_ctx=24):
    """Independent plain-numpy float64 forward (np.dot reductions, libm
    exp/sin/cos) used to sanity-check the bit-exact mirror: structural
    agreement within float tolerance, no shared reduction code."""
    c = TINY_MOE
    nope, rope_d, vh = c["qk_nope_head_dim"], c["qk_rope_head_dim"], c["v_head_dim"]
    kv_rank = c["kv_lora_rank"]
    qk_head = nope + rope_d
    w = {k: np.asarray(v, dtype=np.float64) for k, v in weights.items()}
    inv_freq = 10000.0 ** (-np.arange(0, rope_d, 2) / rope_d)

    def rope(x, pos):
        # Half-split (NeoX) pairing, matching python/compile/model.py.
        ang = pos * inv_freq
        co, si = np.cos(ang), np.sin(ang)
        half = x.size // 2
        out = np.empty_like(x)
        out[:half] = x[:half] * co - x[half:] * si
        out[half:] = x[:half] * si + x[half:] * co
        return out

    def norm(x, g):
        return x / np.sqrt(np.mean(x * x) + 1e-6) * g

    def softmax(x):
        e = np.exp(x - np.max(x))
        return e / e.sum()

    def mlp(li, stem_g, stem_u, stem_d, xn, e=None):
        gw, uw, dw = (w[f"blk.{li}.{s}.weight"] for s in (stem_g, stem_u, stem_d))
        if e is not None:
            gw, uw, dw = gw[e], uw[e], dw[e]
        g = gw @ xn
        a = g / (1.0 + np.exp(-g)) * (uw @ xn)
        return dw @ a

    caches = [np.zeros((max_ctx, kv_rank + rope_d)) for _ in range(c["n_layers"])]
    rows = []
    for pos, tok in enumerate(list(prompt) + list(step_tokens)):
        h = w["token_embd.weight"][tok % c["vocab_size"]].copy()
        for li in range(c["n_layers"]):
            xn = norm(h, w[f"blk.{li}.attn_norm.weight"])
            q = w[f"blk.{li}.attn_q_b.weight"] @ norm(
                w[f"blk.{li}.attn_q_a.weight"] @ xn, w[f"blk.{li}.attn_q_a_norm.weight"]
            )
            kv_a = w[f"blk.{li}.attn_kv_a_mqa.weight"] @ xn
            caches[li][pos, :kv_rank] = norm(
                kv_a[:kv_rank], w[f"blk.{li}.attn_kv_a_norm.weight"]
            )
            caches[li][pos, kv_rank:] = rope(kv_a[kv_rank:], pos)
            ctx = pos + 1
            kvb = caches[li][:ctx, :kv_rank] @ w[f"blk.{li}.attn_kv_b.weight"].T
            heads = np.zeros(c["n_heads"] * vh)
            for hd in range(c["n_heads"]):
                qh = q[hd * qk_head : (hd + 1) * qk_head].copy()
                qh[nope:] = rope(qh[nope:], pos)
                kn = kvb[:, hd * (nope + vh) : hd * (nope + vh) + nope]
                vv = kvb[:, hd * (nope + vh) + nope : hd * (nope + vh) + nope + vh]
                sc = (kn @ qh[:nope] + caches[li][:ctx, kv_rank:] @ qh[nope:]) / np.sqrt(
                    qk_head
                )
                heads[hd * vh : (hd + 1) * vh] = softmax(sc) @ vv
            h = h + w[f"blk.{li}.attn_output.weight"] @ heads
            xn = norm(h, w[f"blk.{li}.ffn_norm.weight"])
            if li < c["first_dense"]:
                h = h + mlp(li, "ffn_gate", "ffn_up", "ffn_down", xn)
            else:
                probs = softmax(w[f"blk.{li}.ffn_gate_inp.weight"] @ xn)
                picked = sorted(
                    range(c["n_routed_experts"]), key=lambda i: (-probs[i], i)
                )[: c["n_active_experts"]]
                picked.sort()
                z = probs[picked].sum()
                y = mlp(li, "ffn_gate_shexp", "ffn_up_shexp", "ffn_down_shexp", xn)
                for e in picked:
                    y = y + probs[e] / z * mlp(
                        li, "ffn_gate_exps", "ffn_up_exps", "ffn_down_exps", xn, e
                    )
                h = h + y
        if pos >= len(prompt) - 1:
            xn = norm(h, w["output_norm.weight"])
            rows.append(w["output.weight"] @ xn)
    return rows


def forward_reference_f64_dense(weights: dict, prompt, step_tokens, max_ctx=24):
    """Independent plain-numpy float64 dense-GQA forward (np.dot
    reductions, libm exp/sin/cos, rope via powers of the configured
    base) used to sanity-check the bit-exact dense mirror."""
    c = TINY_DENSE
    hd, n_kv, nh = c["head_dim"], c["n_kv_heads"], c["n_heads"]
    kd = n_kv * hd
    group = nh // n_kv
    w = {k: np.asarray(v, dtype=np.float64) for k, v in weights.items()}
    inv_freq = float(c["rope_base"]) ** (-np.arange(0, hd, 2) / hd)

    def rope(x, pos):
        # Half-split (NeoX) pairing, matching python/compile/model.py.
        ang = pos * inv_freq
        co, si = np.cos(ang), np.sin(ang)
        half = x.size // 2
        out = np.empty_like(x)
        out[:half] = x[:half] * co - x[half:] * si
        out[half:] = x[:half] * si + x[half:] * co
        return out

    def norm(x, g):
        return x / np.sqrt(np.mean(x * x) + 1e-6) * g

    def softmax(x):
        e = np.exp(x - np.max(x))
        return e / e.sum()

    caches = [np.zeros((max_ctx, 2 * kd)) for _ in range(c["n_layers"])]
    rows = []
    for pos, tok in enumerate(list(prompt) + list(step_tokens)):
        h = w["token_embd.weight"][tok % c["vocab_size"]].copy()
        for li in range(c["n_layers"]):
            xn = norm(h, w[f"blk.{li}.attn_norm.weight"])
            q = w[f"blk.{li}.attn_q.weight"] @ xn
            k = w[f"blk.{li}.attn_k.weight"] @ xn
            v = w[f"blk.{li}.attn_v.weight"] @ xn
            for kh in range(n_kv):
                k[kh * hd : (kh + 1) * hd] = rope(k[kh * hd : (kh + 1) * hd], pos)
            caches[li][pos, :kd] = k
            caches[li][pos, kd:] = v
            ctx = pos + 1
            heads = np.zeros(nh * hd)
            for head in range(nh):
                qh = rope(q[head * hd : (head + 1) * hd], pos)
                kh = head // group
                ks = caches[li][:ctx, kh * hd : (kh + 1) * hd]
                vs = caches[li][:ctx, kd + kh * hd : kd + (kh + 1) * hd]
                sc = softmax(ks @ qh / np.sqrt(hd))
                heads[head * hd : (head + 1) * hd] = sc @ vs
            h = h + w[f"blk.{li}.attn_output.weight"] @ heads
            xn = norm(h, w[f"blk.{li}.ffn_norm.weight"])
            g = w[f"blk.{li}.ffn_gate.weight"] @ xn
            a = g / (1.0 + np.exp(-g)) * (w[f"blk.{li}.ffn_up.weight"] @ xn)
            h = h + w[f"blk.{li}.ffn_down.weight"] @ a
        if pos >= len(prompt) - 1:
            xn = norm(h, w["output_norm.weight"])
            rows.append(w["output.weight"] @ xn)
    return rows


def rel_l2(a, b) -> float:
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30))


# ---------------------------------------------------------------------------
# Cross-checks
# ---------------------------------------------------------------------------


def check_pcg():
    from compile import tasks

    theirs = tasks.Pcg(42)
    mine = Pcg(42)
    for _ in range(64):
        assert mine.next_u64() == theirs.next_u64(), "Pcg mirror drift vs tasks.py"


def check_f16():
    rng = np.random.default_rng(0)
    samples = rng.integers(0, 1 << 32, size=1_000_000, dtype=np.uint64).astype(np.uint32)
    v = samples.view(F32)
    finite = np.isfinite(v)
    mine = f32_to_f16_bits(v[finite])
    with np.errstate(over="ignore"):
        numpy_bits = v[finite].astype(np.float16).view(np.uint16)
    # util::f16 flushes |x| < 2^-24 to signed zero (its `unbiased < -24`
    # early-out), including the (2^-25, 2^-24) sliver that strict
    # round-to-nearest takes up to the smallest denormal — the mirror
    # must match the Rust code, not IEEE, there.
    sliver = np.abs(v[finite].astype(np.float64)) < 2.0**-24
    agree = mine == numpy_bits
    assert np.all(agree | sliver), "f16 conversion mismatch vs numpy"
    assert np.all((mine[sliver] & 0x7FFF) == 0), "f16 sliver must flush to zero"


def check_search_scalar_vs_vector():
    rng = Pcg(0xC0FFEE)
    for n, nmax_list in [(16, [3, 4, 32]), (32, [15, 31])]:
        for case in range(40):
            scale = F32(10.0) ** (int(rng.next_u64() % 7) - 3)
            x = rng.normals(n, 1.0) * scale
            if case % 4 == 0:
                x[0] = F32(0.0)
            if case % 5 == 0:
                x[:] = F32(abs(float(x[1])) + 1.0)  # constant block
            w = np.array([F32(rng.next_f32() + F32(0.05)) for _ in range(n)], dtype=F32)
            for nmax in nmax_list:
                for weights in (None, w):
                    if n == 16 and nmax in (4, 32):
                        a = make_qx_quants_scales(x.reshape(1, n), nmax, None if weights is None else weights.reshape(1, n))[0]
                        b = make_qx_quants_scalar(x, nmax, weights)
                        assert F32(a).tobytes() == F32(b).tobytes(), (
                            "qx scalar/vector drift",
                            n,
                            nmax,
                            case,
                        )
                    a_s, a_m = make_qkx_quants_scales(
                        x.reshape(1, n), nmax, None if weights is None else weights.reshape(1, n)
                    )
                    b_s, b_m = make_qkx_quants_scalar(x, nmax, weights)
                    assert (
                        F32(a_s[0]).tobytes() == F32(b_s).tobytes()
                        and F32(a_m[0]).tobytes() == F32(b_m).tobytes()
                    ), ("qkx scalar/vector drift", n, nmax, case)


def check_roundtrip(fmt: str, data: np.ndarray, payload: np.ndarray, label: str):
    """Decode through the independent python/compile/quants.py mirror."""
    if fmt == "f32":
        deq = payload.view(F32)
    else:
        deq = pyquants.dequantize(fmt, payload, data.size)
    if fmt in ("f32", "f16"):
        atol = 0.0 if fmt == "f32" else None
        if fmt == "f32":
            assert np.array_equal(deq, data), label
        else:
            assert np.allclose(deq, data, rtol=1e-3, atol=1e-6), label
        return
    num = float(np.mean((data.astype(np.float64) - deq.astype(np.float64)) ** 2))
    den = float(np.mean(data.astype(np.float64) ** 2))
    rel = math.sqrt(num / den) if den > 0 else 0.0
    # Looser than the gaussian-only unit-test bounds: the golden input
    # deliberately mixes ±20σ outliers into 0.1-scale bulk.
    bound = {"q8_0": 0.02, "q6_k": 0.06, "q5_k": 0.09, "q4_k": 0.15, "q3_k": 0.25, "q2_k": 0.45}[fmt]
    assert rel < bound, (label, rel, bound)


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main():
    check_only = "--check-only" in sys.argv
    print("· cross-checking Pcg against python/compile/tasks.py")
    check_pcg()
    print("· cross-checking f16 conversion against numpy (1M samples)")
    check_f16()
    print("· cross-checking vectorized search against scalar transcription")
    check_search_scalar_vs_vector()

    outputs: dict[str, bytes | str] = {}

    # Per-format fixtures.
    for fmt in FORMATS:
        data, imp = golden_input(fmt)
        for variant, weights in (("plain", None), ("imatrix", imp)):
            payload = quantize(fmt, data, weights)
            check_roundtrip(fmt, data, payload, f"{fmt}.{variant}")
            outputs[f"{fmt}.{variant}.hex"] = hex_fixture(payload)
    print(f"· encoded {len(FORMATS)}×2 format fixtures (roundtrip-checked)")

    # Container checksums.
    census = tiny_moe_census()
    rng = Pcg(0x601D)
    tensor_values = {}
    for name, _cls, _layer, shape in census:
        n = int(np.prod(shape))
        tensor_values[name] = rng.normals(n, 0.05)
    print(f"· generated synthetic tiny-moe weights ({sum(v.size for v in tensor_values.values())} f32)")

    for scheme_name in ("dq3_k_m", "q4_k_m"):
        # Cross-check assignment against the independent schemes.py mirror.
        scheme = load_scheme(scheme_name)

        class _Cfg:
            n_layers = TINY_MOE["n_layers"]
            first_dense = TINY_MOE["first_dense"]

        for name, cls, layer, shape in census:
            mine = assign(scheme, cls, layer, shape)
            theirs = pyschemes.assign(
                scheme, cls, layer, shape[-1], int(np.prod(shape)), _Cfg
            )
            assert mine == theirs, (scheme_name, name, mine, theirs)

        quantized = quantize_census(scheme_name, tensor_values)
        blob = build_container(scheme_name, quantized)
        # Sanity: parse with the independent container reader + decode spot
        # tensors through the independent dequantizer.
        from compile import container as pycontainer

        tmp = GOLDEN_DIR / f".tmp.{scheme_name}.dsq"
        tmp.write_bytes(blob)
        try:
            c = pycontainer.Container.open(tmp)
            assert c.scheme == scheme_name and c.model["name"] == "tiny-moe"
            for e in c.entries[:: max(1, len(c.entries) // 7)]:
                deq = c.dequantize(e).reshape(-1)
                src = tensor_values[e.name]
                if e.fmt == "f32":
                    assert np.array_equal(deq, src), e.name
                else:
                    num = float(np.mean((src.astype(np.float64) - deq.astype(np.float64)) ** 2))
                    den = float(np.mean(src.astype(np.float64) ** 2))
                    assert math.sqrt(num / den) < 0.45, (e.name, e.fmt)
        finally:
            tmp.unlink(missing_ok=True)
        line = f"{fnv64(blob):016x} {len(blob)}\n"
        outputs[f"container.{scheme_name}.fnv64"] = line
        print(f"· container {scheme_name}: {len(blob)} bytes, fnv64 {line.split()[0]}")

        # Forward-pass golden: the bit-exact mirror of the native
        # tiny-MoE forward over this scheme's encoded weights (prefill
        # FORWARD_PROMPT + greedy decode; hash every emitted logits row).
        fwd = ForwardMirror(quantized)
        rows = fwd.run(FORWARD_PROMPT, FORWARD_DECODE_STEPS)
        fwd_blob = b"".join(np.ascontiguousarray(r, dtype=F32).tobytes() for r in rows)
        fwd_line = f"{fnv64(fwd_blob):016x} {len(fwd_blob)}\n"
        outputs[f"forward.{scheme_name}.fnv64"] = fwd_line
        print(
            f"· forward {scheme_name}: {len(rows)} logits rows, fnv64 {fwd_line.split()[0]}"
        )

        if scheme_name == "q4_k_m":
            # Quantized-KV forward golden: the same script with the KV
            # cache held in q8_0 (quantize-on-append, decoded reads).
            # This is the ONLY bless path for forward.kv_q8_0.* — the
            # Rust suite fails, never self-blesses, when it is missing.
            fwd_q8 = ForwardMirror(quantized, kv_scheme="q8_0")
            q8_rows = fwd_q8.run(FORWARD_PROMPT, FORWARD_DECODE_STEPS)
            q8_blob = b"".join(
                np.ascontiguousarray(r, dtype=F32).tobytes() for r in q8_rows
            )
            q8_line = f"{fnv64(q8_blob):016x} {len(q8_blob)}\n"
            outputs[f"forward.kv_q8_0.{scheme_name}.fnv64"] = q8_line
            kv_drift = rel_l2(q8_rows[0], rows[0])
            assert q8_blob != fwd_blob, "q8_0 KV unexpectedly bit-identical to f32 KV"
            assert kv_drift < 0.05, f"q8_0 KV drift vs f32 KV out of band: {kv_drift}"
            print(
                f"· forward kv_q8_0 {scheme_name}: {len(q8_rows)} logits rows, "
                f"fnv64 {q8_line.split()[0]} (prefill-row rel-L2 vs f32 KV "
                f"{kv_drift:.2e})"
            )

        # Independent structural check: a plain-numpy float64 forward
        # (np.dot reductions, libm transcendentals — no shared code)
        # over the same decoded weights must agree within float
        # tolerance; and over the f32 source weights within the
        # quantization-error band (reported for the Rust differential
        # suite's thresholds).
        step_toks = [int(np.argmax(rows[i])) for i in range(FORWARD_DECODE_STEPS)]
        ref_rows = forward_reference_f64(fwd.w, FORWARD_PROMPT, step_toks)
        worst = max(rel_l2(a, b) for a, b in zip(rows, ref_rows))
        assert worst < 2e-3, f"mirror vs f64 reference drift: {worst}"
        src_w = {
            name: tensor_values[name].reshape(shape)
            for name, _cls, _layer, shape in census
        }
        src_rows = forward_reference_f64(src_w, FORWARD_PROMPT, step_toks)
        qerr = max(rel_l2(a, b) for a, b in zip(rows, src_rows))
        print(
            f"  forward {scheme_name}: f64-reference rel-L2 {worst:.2e}, "
            f"quantization rel-L2 vs f32 weights {qerr:.3f}"
        )

    # Dense-GQA forward goldens (the Table-5 tiny-dense proxy): the
    # same seed's synthetic weights over the dense census, quantized per
    # scheme and run through the GQA branch of the bit-exact mirror —
    # producing the forward.tiny_dense.*.fnv64 fixtures that pin the
    # Rust dense forward pass cross-language.
    dense_census = tiny_dense_census()
    rng = Pcg(0x601D)
    dense_values = {}
    for name, _cls, _layer, shape in dense_census:
        n = int(np.prod(shape))
        dense_values[name] = rng.normals(n, 0.05)
    print(
        "· generated synthetic tiny-dense weights "
        f"({sum(v.size for v in dense_values.values())} f32)"
    )

    for scheme_name in ("dq3_k_m", "q4_k_m"):
        scheme = load_scheme(scheme_name)

        class _DenseCfg:
            n_layers = TINY_DENSE["n_layers"]
            first_dense = TINY_DENSE["first_dense"]

        for name, cls, layer, shape in dense_census:
            mine = assign(scheme, cls, layer, shape, TINY_DENSE)
            theirs = pyschemes.assign(
                scheme, cls, layer, shape[-1], int(np.prod(shape)), _DenseCfg
            )
            assert mine == theirs, (scheme_name, name, mine, theirs)

        quantized = quantize_census(scheme_name, dense_values, dense_census, TINY_DENSE)
        fwd = ForwardMirror(quantized, TINY_DENSE)
        rows = fwd.run(FORWARD_PROMPT, FORWARD_DECODE_STEPS)
        fwd_blob = b"".join(np.ascontiguousarray(r, dtype=F32).tobytes() for r in rows)
        fwd_line = f"{fnv64(fwd_blob):016x} {len(fwd_blob)}\n"
        outputs[f"forward.tiny_dense.{scheme_name}.fnv64"] = fwd_line
        print(
            f"· forward tiny-dense {scheme_name}: {len(rows)} logits rows, "
            f"fnv64 {fwd_line.split()[0]}"
        )

        if scheme_name == "q4_k_m":
            # Quantized-KV golden for the GQA branch (whole [K|V] row
            # encoded on append) — mirror-only bless, as for tiny-moe.
            fwd_q8 = ForwardMirror(quantized, TINY_DENSE, kv_scheme="q8_0")
            q8_rows = fwd_q8.run(FORWARD_PROMPT, FORWARD_DECODE_STEPS)
            q8_blob = b"".join(
                np.ascontiguousarray(r, dtype=F32).tobytes() for r in q8_rows
            )
            q8_line = f"{fnv64(q8_blob):016x} {len(q8_blob)}\n"
            outputs[f"forward.kv_q8_0.tiny_dense.{scheme_name}.fnv64"] = q8_line
            kv_drift = rel_l2(q8_rows[0], rows[0])
            assert q8_blob != fwd_blob, "q8_0 KV unexpectedly bit-identical to f32 KV"
            assert kv_drift < 0.05, f"q8_0 KV drift vs f32 KV out of band: {kv_drift}"
            print(
                f"· forward kv_q8_0 tiny-dense {scheme_name}: {len(q8_rows)} logits "
                f"rows, fnv64 {q8_line.split()[0]} (prefill-row rel-L2 vs f32 KV "
                f"{kv_drift:.2e})"
            )

        # Independent structural check, exactly as for tiny-moe: a
        # plain-numpy float64 GQA forward over the same decoded weights
        # must agree within float tolerance, and the drift vs the f32
        # source weights must sit in the quantization-error band.
        step_toks = [int(np.argmax(rows[i])) for i in range(FORWARD_DECODE_STEPS)]
        ref_rows = forward_reference_f64_dense(fwd.w, FORWARD_PROMPT, step_toks)
        worst = max(rel_l2(a, b) for a, b in zip(rows, ref_rows))
        assert worst < 2e-3, f"dense mirror vs f64 reference drift: {worst}"
        src_w = {
            name: dense_values[name].reshape(shape)
            for name, _cls, _layer, shape in dense_census
        }
        src_rows = forward_reference_f64_dense(src_w, FORWARD_PROMPT, step_toks)
        qerr = max(rel_l2(a, b) for a, b in zip(rows, src_rows))
        print(
            f"  forward tiny-dense {scheme_name}: f64-reference rel-L2 {worst:.2e}, "
            f"quantization rel-L2 vs f32 weights {qerr:.3f}"
        )

    if check_only:
        drift = []
        for fname, content in outputs.items():
            path = GOLDEN_DIR / fname
            if not path.exists() or path.read_text() != content:
                drift.append(fname)
        if drift:
            print(f"DRIFT vs committed fixtures: {drift}")
            sys.exit(1)
        print("all committed fixtures match the mirror")
        return

    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    for fname, content in outputs.items():
        (GOLDEN_DIR / fname).write_text(content)
        print(f"  blessed {fname}")
    print(f"wrote {len(outputs)} fixtures → {GOLDEN_DIR}")


if __name__ == "__main__":
    main()
